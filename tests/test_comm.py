"""Communication-layer contracts (repro.comm) + the compressed engine backend.

Pins the compressor algebra the theory relies on and the engine guarantee
that compression at ratio 1.0 is a no-op:

  * rand-k is unbiased:  E_key[C(x)] = x  (mean over a key grid);
  * top-k is a contraction:  ||C(x) - x||^2 <= (1 - k/d) ||x||^2;
  * error feedback telescopes exactly:  sum_t C_t = sum_t m_t - e_T;
  * ``backend="compressed"`` at compression ratio 1.0 reproduces the inline
    trajectory bit-for-bit, threads compressor state across chunk
    boundaries, and at ratio < 1 stays within a recorded residual envelope
    while still training.
"""
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st
from repro.comm import (Dense, DownlinkCompressor, Quantize, RandK, TopK,
                        broadcast_elements, get_transport,
                        message_elements_per_client, uplink_message_spec)
from repro.core import algorithm as A
from repro.core.baselines import FastFedDA, Scaffold
from repro.core.prox import L1
from repro.data.synthetic import logistic_heterogeneous
from repro.exec import ArraySupplier, EngineConfig, RoundEngine
from repro.fed.simulator import DProxAlgorithm
from repro.models import logreg
from repro.utils import tree as tu


def _msg(seed=0, n=3, d=40):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n, d))),
            "b": jnp.asarray(rng.normal(size=(n,)))}


def _problem(n=6, m=30, d=10, seed=0, lam=0.01):
    data = logistic_heterogeneous(
        n_clients=n, m_per_client=m, d=d, alpha=5, beta=5, seed=seed)
    s = np.linalg.norm(data.features.reshape(-1, d), axis=1).max()
    data.features = (data.features / s).astype(np.float64)
    data.labels = data.labels.astype(np.float64)
    reg = L1(lam=lam)
    grad_fn = logreg.make_grad_fn()
    params0 = {"w": jnp.zeros(d, jnp.float64), "b": jnp.zeros((), jnp.float64)}
    return data, reg, grad_fn, params0


def _dprox(reg, tau=3, eta=0.05, eta_g=2.0):
    return DProxAlgorithm(reg, A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g))


def _run(engine, params0, supplier, rounds):
    state = engine.init(params0)
    return engine.run(state, supplier, rounds, seed=0)


# ---------------------------------------------------------------------------
# compressor algebra
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=8)
@given(ratio=st.floats(0.05, 0.95))
def test_randk_unbiased_in_expectation_over_keys(ratio):
    tr = RandK(ratio=ratio, error_feedback=False)
    msg = _msg()
    keys = jax.random.split(jax.random.PRNGKey(7), 4096)
    mean = jax.tree_util.tree_map(
        lambda x: jnp.mean(x, axis=0),
        jax.vmap(lambda k: tr.apply(msg, k))(keys))
    # estimator std per coord is |x| sqrt((d/k - 1)/N); 4096 keys with
    # |x| ~ N(0,1) keeps 5-sigma well under 0.35 for the grid's ratios
    for k in msg:
        err = float(jnp.max(jnp.abs(mean[k] - msg[k])))
        assert err < 0.35, (ratio, k, err)


@settings(deadline=None, max_examples=8)
@given(ratio=st.floats(0.05, 1.0))
def test_topk_contraction_factor(ratio):
    tr = TopK(ratio=ratio, error_feedback=False)
    msg = _msg(seed=3)
    out = tr.apply(msg, jax.random.PRNGKey(0))
    x = np.asarray(msg["w"])
    cx = np.asarray(out["w"])
    d = x.shape[1]
    k = max(1, min(d, int(round(ratio * d))))
    for row in range(x.shape[0]):
        lhs = np.sum((cx[row] - x[row]) ** 2)
        rhs = (1.0 - k / d) * np.sum(x[row] ** 2)
        assert lhs <= rhs + 1e-12, (ratio, row, lhs, rhs)


def test_topk_keeps_largest_magnitudes():
    tr = TopK(ratio=0.5, error_feedback=False)
    x = {"v": jnp.asarray([[1.0, -4.0, 0.5, 3.0]])}
    out = np.asarray(tr.apply(x, jax.random.PRNGKey(0))["v"])
    np.testing.assert_array_equal(out, [[0.0, -4.0, 0.0, 3.0]])


@settings(deadline=None, max_examples=6)
@given(bits=st.integers(2, 8))
def test_quantize_unbiased_and_bounded(bits):
    tr = Quantize(bits=bits, error_feedback=False)
    msg = {"w": _msg(seed=5)["w"]}
    keys = jax.random.split(jax.random.PRNGKey(11), 2048)
    outs = jax.vmap(lambda k: tr.apply(msg, k)["w"])(keys)
    mean = np.asarray(jnp.mean(outs, axis=0))
    x = np.asarray(msg["w"])
    s = np.max(np.abs(x), axis=1, keepdims=True)
    step = s / ((1 << bits) - 1)
    # stochastic rounding: unbiased, and every draw within one level
    assert np.max(np.abs(mean - x)) < 5 * float(np.max(step)) / np.sqrt(2048) * 10
    assert float(jnp.max(jnp.abs(outs - x[None]))) <= float(np.max(step)) + 1e-12


@pytest.mark.parametrize("tr", [
    Dense(), TopK(ratio=1.0), RandK(ratio=1.0), TopK(ratio=1.0, error_feedback=False),
], ids=["dense", "topk1", "randk1", "topk1_noef"])
def test_ratio_one_transports_are_exact_identity(tr):
    msg = _msg(seed=9)
    state = tr.init_state(msg)
    out, state2 = tr.compress(state, msg, jax.random.PRNGKey(0))
    for k in msg:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(msg[k]))
    # and the error-feedback residual stays exactly zero
    for leaf in jax.tree_util.tree_leaves(state2):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0


@pytest.mark.parametrize("tr", [
    TopK(ratio=0.3), RandK(ratio=0.3), Quantize(bits=4),
], ids=["topk", "randk", "quantize"])
def test_error_feedback_summation_identity(tr):
    """sum_t m_hat_t = sum_t m_t - e_T  (telescoping, exact in fp64)."""
    msgs = [_msg(seed=s) for s in range(6)]
    state = tr.init_state(msgs[0])
    sent = tu.tree_zeros_like(msgs[0])
    key = jax.random.PRNGKey(3)
    for m in msgs:
        key, sub = jax.random.split(key)
        m_hat, state = tr.compress(state, m, sub)
        sent = tu.tree_add(sent, m_hat)
    total = msgs[0]
    for m in msgs[1:]:
        total = tu.tree_add(total, m)
    for k in total:
        np.testing.assert_allclose(
            np.asarray(sent[k]) + np.asarray(state[k]), np.asarray(total[k]),
            rtol=1e-10, atol=1e-10)


def test_get_transport_registry():
    assert isinstance(get_transport("topk", ratio=0.2), TopK)
    assert isinstance(get_transport("dense"), Dense)
    with pytest.raises(ValueError, match="unknown transport"):
        get_transport("morse")


X32_SCRIPT = r"""
import jax  # NOTE: x64 deliberately NOT enabled -- float32 is the point
import jax.numpy as jnp
import numpy as np
from repro.comm import Dense, Quantize, RandK, TopK
from repro.utils import tree as tu

rng = np.random.default_rng(0)
msg = {"w": jnp.asarray(rng.normal(size=(3, 40)), jnp.float32),
       "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
for tr in (Dense(), TopK(ratio=0.3), RandK(ratio=0.3), Quantize(bits=4)):
    state = tr.init_state(msg)
    sent = tu.tree_zeros_like(msg)
    total = tu.tree_zeros_like(msg)
    key = jax.random.PRNGKey(1)
    for s in range(5):
        m = {k: v + jnp.float32(0.01 * s) for k, v in msg.items()}
        key, sub = jax.random.split(key)
        m_hat, state = tr.compress(state, m, sub)
        # no silent upcast anywhere in the compressor / error-feedback path
        for k in m_hat:
            assert m_hat[k].dtype == jnp.float32, (tr.name, k, m_hat[k].dtype)
        for leaf in jax.tree_util.tree_leaves(state):
            assert leaf.dtype == jnp.float32, (tr.name, leaf.dtype)
        sent = tu.tree_add(sent, m_hat)
        total = tu.tree_add(total, m)
    if tr.error_feedback:  # telescoping holds at f32 precision
        for k in total:
            np.testing.assert_allclose(
                np.asarray(sent[k]) + np.asarray(state[k]),
                np.asarray(total[k]), rtol=2e-5, atol=2e-5)
print("COMM_X32_OK")
"""


def test_compressor_path_holds_in_float32():
    """Dtype-drift guard: the compressor/error-feedback path must stay in
    the message dtype (f32 here) -- a silent upcast would make accelerator
    runs ship doubled bytes and break donation.  Runs in a subprocess so the
    module-level x64 flag of this file does not leak in."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_ENABLE_X64", None)
    out = subprocess.run([sys.executable, "-c", X32_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "COMM_X32_OK" in out.stdout


# ---------------------------------------------------------------------------
# message specs / byte accounting
# ---------------------------------------------------------------------------


def test_uplink_message_spec_counts_vectors():
    data, reg, grad_fn, params0 = _problem()
    d_model = 11  # w(10) + b(1)
    batch = {"a": jax.ShapeDtypeStruct((6, 3, 8, 10), jnp.float64),
             "y": jax.ShapeDtypeStruct((6, 3, 8), jnp.float64)}
    algs = [
        (_dprox(reg), 1), (FastFedDA(reg, tau=3, eta0=0.05), 2),
        (Scaffold(reg, tau=3, eta=0.05), 2),
    ]
    for alg, vectors in algs:
        state = alg.init(params0, 6)
        spec = uplink_message_spec(alg, grad_fn, state, batch)
        assert message_elements_per_client(spec) == vectors * d_model, alg.name


def test_engine_reports_transport_bytes():
    data, reg, grad_fn, params0 = _problem()
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=1)
    for tr, expect in [(Dense(), 11 * 8), (TopK(ratio=0.5), 5 * 12 + 1 * 12)]:
        eng = RoundEngine(_dprox(reg), grad_fn, data.n_clients,
                          EngineConfig(backend="compressed", chunk_rounds=2,
                                       transport=tr))
        _run(eng, params0, sup, 2)
        assert eng.uplink_bytes_per_client_round == expect, tr.name


# ---------------------------------------------------------------------------
# compressed engine backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tr", [None, TopK(ratio=1.0), RandK(ratio=1.0)],
                         ids=["dense_default", "topk1", "randk1"])
def test_compressed_ratio_one_matches_inline(tr):
    data, reg, grad_fn, params0 = _problem(seed=1)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=2)
    alg = _dprox(reg)
    s_in, m_in = _run(
        RoundEngine(alg, grad_fn, data.n_clients,
                    EngineConfig(chunk_rounds=3)), params0, sup, 7)
    s_c, m_c = _run(
        RoundEngine(alg, grad_fn, data.n_clients,
                    EngineConfig(backend="compressed", chunk_rounds=3,
                                 transport=tr)), params0, sup, 7)
    np.testing.assert_allclose(np.asarray(s_in.x_bar["w"]),
                               np.asarray(s_c.x_bar["w"]), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(s_in.c["w"]),
                               np.asarray(s_c.c["w"]), rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(m_in["train_loss"], m_c["train_loss"],
                               rtol=1e-6)


def test_compressed_trajectory_invariant_to_chunking():
    """Compressor state + PRNG key thread through the scan carry and across
    chunk boundaries: the trajectory must not depend on chunk_rounds."""
    data, reg, grad_fn, params0 = _problem(seed=2)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=3)
    alg = _dprox(reg)
    states = []
    for ch in (1, 4):
        eng = RoundEngine(alg, grad_fn, data.n_clients,
                          EngineConfig(backend="compressed", chunk_rounds=ch,
                                       transport=RandK(ratio=0.5)))
        states.append(_run(eng, params0, sup, 6)[0])
    np.testing.assert_allclose(np.asarray(states[0].x_bar["w"]),
                               np.asarray(states[1].x_bar["w"]),
                               rtol=1e-12, atol=1e-14)


def test_compressed_ratio_below_one_bounded_residual():
    """TopK(0.5)+error feedback stays within a recorded envelope of the
    dense trajectory while still training (recorded residual 0.324 on this
    problem/seed; envelope ~1.7x)."""
    data, reg, grad_fn, params0 = _problem(seed=0)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=1)
    alg = _dprox(reg)
    s_in, _ = _run(RoundEngine(alg, grad_fn, data.n_clients,
                               EngineConfig(chunk_rounds=4)),
                   params0, sup, 20)
    eng = RoundEngine(alg, grad_fn, data.n_clients,
                      EngineConfig(backend="compressed", chunk_rounds=4,
                                   transport=TopK(ratio=0.5)))
    s_c, m_c = _run(eng, params0, sup, 20)
    w_in, w_c = np.asarray(s_in.x_bar["w"]), np.asarray(s_c.x_bar["w"])
    rel = float(np.linalg.norm(w_c - w_in) / np.linalg.norm(w_in))
    assert 0.0 < rel < 0.55, rel  # envelope: ~1.7x the recorded 0.324
    losses = m_c["train_loss"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_compressed_supports_partial_participation():
    data, reg, grad_fn, params0 = _problem(seed=4)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=5)
    alg = _dprox(reg)
    # full participation through the compressed path == dense inline
    s_in, _ = _run(RoundEngine(alg, grad_fn, data.n_clients,
                               EngineConfig(chunk_rounds=2,
                                            participation=1.0)),
                   params0, sup, 4)
    s_c, _ = _run(RoundEngine(alg, grad_fn, data.n_clients,
                              EngineConfig(backend="compressed",
                                           chunk_rounds=2, participation=1.0,
                                           transport=RandK(ratio=1.0))),
                  params0, sup, 4)
    np.testing.assert_allclose(np.asarray(s_in.x_bar["w"]),
                               np.asarray(s_c.x_bar["w"]),
                               rtol=1e-12, atol=1e-14)
    # subsampled clients still train and stay finite
    eng = RoundEngine(alg, grad_fn, data.n_clients,
                      EngineConfig(backend="compressed", chunk_rounds=2,
                                   participation=0.5,
                                   transport=TopK(ratio=0.5)))
    state, metrics = _run(eng, params0, sup, 8)
    assert np.isfinite(metrics["train_loss"]).all()
    assert bool(tu.tree_isfinite(state.x_bar))


def test_inactive_clients_keep_error_feedback_residuals():
    """Non-participants transmit nothing, so their error-feedback state must
    not advance (else the telescoping identity breaks per skipped round)."""
    data, reg, grad_fn, params0 = _problem(seed=6)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=7)
    alg = _dprox(reg)
    eng = RoundEngine(alg, grad_fn, data.n_clients,
                      EngineConfig(backend="compressed", chunk_rounds=1,
                                   participation=0.5,
                                   transport=TopK(ratio=0.3)))
    state = eng.init(params0)
    # warm up so active clients accumulate nonzero residuals
    active = np.zeros(data.n_clients, bool)
    active[:2] = True
    state, _ = eng.step(state, sup.sample_round(0, None), active=active)
    res = np.asarray(eng._comm_state["w"])
    assert np.abs(res[:2]).max() > 0  # participants dropped some mass
    np.testing.assert_array_equal(res[2:], 0.0)  # non-participants frozen
    frozen = res[2:].copy()
    state, _ = eng.step(state, sup.sample_round(1, None), active=active)
    np.testing.assert_array_equal(
        np.asarray(eng._comm_state["w"])[2:], frozen)


# ---------------------------------------------------------------------------
# downlink compression
# ---------------------------------------------------------------------------


def test_downlink_identity_tracks_state_bitwise():
    """At ratio 1.0 the client-visible shadow equals the true server state
    bitwise (the subtractive seen-update form guarantees it)."""
    dl = DownlinkCompressor(TopK(ratio=1.0))
    rng = np.random.default_rng(0)
    fields = {"x_bar": {"w": jnp.asarray(rng.normal(size=7))}}
    st = dl.init_state(fields)
    key = jax.random.PRNGKey(0)
    for s in range(4):
        fields = {"x_bar": {"w": fields["x_bar"]["w"] + 0.1 * s - 0.05}}
        key, sub = jax.random.split(key)
        visible, st = dl.broadcast(st, fields, sub)
        np.testing.assert_array_equal(np.asarray(visible["x_bar"]["w"]),
                                      np.asarray(fields["x_bar"]["w"]))


def test_downlink_shadow_residual_telescopes():
    """seen accumulates exactly what was broadcast: the standing residual
    x_true - seen IS the error-feedback state, so each innovation re-sends
    everything previously dropped (no separate residual stream needed)."""
    dl = DownlinkCompressor(TopK(ratio=0.4))
    rng = np.random.default_rng(1)
    fields = {"w": jnp.asarray(rng.normal(size=10))}
    st = dl.init_state(fields)
    key = jax.random.PRNGKey(1)
    for s in range(6):
        fields = {"w": fields["w"] + jnp.asarray(rng.normal(size=10)) * 0.3}
        key, sub = jax.random.split(key)
        visible, st = dl.broadcast(st, fields, sub)
    # one dense broadcast closes the gap completely: the residual was the
    # only thing outstanding
    visible, _ = DownlinkCompressor(Dense()).broadcast(st, fields, key)
    np.testing.assert_allclose(np.asarray(visible["w"]),
                               np.asarray(fields["w"]), rtol=1e-12)


def test_engine_downlink_ratio_one_matches_compressed():
    data, reg, grad_fn, params0 = _problem(seed=3)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=4)
    alg = _dprox(reg)
    s_c, m_c = _run(RoundEngine(alg, grad_fn, data.n_clients,
                                EngineConfig(backend="compressed",
                                             chunk_rounds=3)),
                    params0, sup, 7)
    s_d, m_d = _run(RoundEngine(alg, grad_fn, data.n_clients,
                                EngineConfig(backend="compressed",
                                             chunk_rounds=3,
                                             downlink=Dense())),
                    params0, sup, 7)
    np.testing.assert_array_equal(np.asarray(s_c.x_bar["w"]),
                                  np.asarray(s_d.x_bar["w"]))
    np.testing.assert_array_equal(m_c["train_loss"], m_d["train_loss"])


def test_engine_downlink_topk_trains_and_reports_bytes():
    data, reg, grad_fn, params0 = _problem(seed=5)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=6)
    alg = _dprox(reg)
    eng = RoundEngine(alg, grad_fn, data.n_clients,
                      EngineConfig(backend="compressed", chunk_rounds=4,
                                   transport=TopK(ratio=0.5),
                                   downlink=TopK(ratio=0.5)))
    state, metrics = _run(eng, params0, sup, 20)
    losses = metrics["train_loss"]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert bool(tu.tree_isfinite(state.x_bar))
    # broadcast = x_bar (11 doubles): top-k half keeps 6 (value + idx)
    assert eng.downlink_bytes_per_client_round == 6 * (8 + 4)
    assert eng.uplink_bytes_per_client_round == 6 * (8 + 4)


def test_downlink_trajectory_invariant_to_chunking():
    data, reg, grad_fn, params0 = _problem(seed=6)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=7)
    alg = _dprox(reg)
    states = []
    for ch in (1, 4):
        eng = RoundEngine(alg, grad_fn, data.n_clients,
                          EngineConfig(backend="compressed", chunk_rounds=ch,
                                       downlink=TopK(ratio=0.5)))
        states.append(_run(eng, params0, sup, 6)[0])
    np.testing.assert_array_equal(np.asarray(states[0].x_bar["w"]),
                                  np.asarray(states[1].x_bar["w"]))


def test_broadcast_elements_and_downlink_bytes():
    fields = {"x_bar": {"w": jnp.zeros(10, jnp.float32),
                        "b": jnp.zeros((), jnp.float32)}}
    assert broadcast_elements(fields) == 11
    assert DownlinkCompressor(Dense()).downlink_bytes(fields) == 11 * 4
    # since the stage refactor, downlink= activates the DownlinkComm stage
    # anywhere (it composes with asynchrony instead of being rejected)
    stack = EngineConfig(downlink=Dense()).resolve()
    assert stack.downlink is not None and stack.uplink is not None
    stack = EngineConfig(downlink=Dense(), clock="straggler").resolve()
    assert stack.downlink is not None and stack.asynchrony is not None


def test_compressed_requires_split_and_jit():
    data, reg, grad_fn, params0 = _problem()

    class NoSplit(DProxAlgorithm):
        def make_local_fn(self, grad_fn):
            raise NotImplementedError

    with pytest.raises(ValueError, match="local/server split"):
        RoundEngine(NoSplit(reg, A.DProxConfig(tau=2, eta=0.05, eta_g=2.0)),
                    grad_fn, data.n_clients,
                    EngineConfig(backend="compressed"))
    with pytest.raises(ValueError, match="jit"):
        EngineConfig(backend="compressed", jit=False).validate()
    with pytest.raises(ValueError, match="Transport"):
        EngineConfig(backend="compressed", transport=object()).validate()
    # since the stage refactor a bare transport= activates the UplinkComm
    # stage (the old inline-backend rejection is gone)
    assert EngineConfig(transport=Dense()).resolve().uplink is not None
