"""Tests for the beyond-paper extensions: partial client participation and
the nuclear-norm regularizer."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithm as A
from repro.core.prox import L1, Nuclear
from repro.data.synthetic import make_round_batches
from repro.models import logreg
from repro.utils import tree as tu


def _problem():
    from benchmarks.common import logreg_problem

    return logreg_problem(n_clients=8, m=60, d=12, lam=0.005, x64=True)


def test_partial_participation_converges_near_full():
    data, reg, grad_fn, full_g, params0, L = _problem()
    tau, eta_g = 5, 3.0
    eta_tilde = 0.4 / L
    cfg = A.DProxConfig(tau=tau, eta=eta_tilde / (eta_g * tau), eta_g=eta_g)
    round_fn = jax.jit(A.make_round_fn(cfg, reg, grad_fn))
    rng = np.random.default_rng(0)
    from repro.core.metrics import prox_gradient_norm

    floors = {}
    for frac in (1.0, 0.5):
        state = A.init_state(params0, 8)
        for r in range(800):
            batches = make_round_batches(data, tau, None, rng)
            if frac >= 1.0:
                active = None
            else:
                act = np.zeros(8, bool)
                act[rng.choice(8, size=4, replace=False)] = True
                active = jnp.asarray(act)
            state, _ = round_fn(state, batches, active)
        x = A.global_params(reg, cfg, state)
        floors[frac] = float(prox_gradient_norm(reg, full_g, x, cfg.eta_tilde))
    # 50% participation converges, within ~2 orders of the full-participation
    # floor (stale corrections add a residual, as documented)
    assert floors[0.5] < 1e-3, floors
    assert floors[0.5] < 1e3 * max(floors[1.0], 1e-12), floors


def test_partial_participation_nonparticipants_keep_state():
    data, reg, grad_fn, full_g, params0, L = _problem()
    cfg = A.DProxConfig(tau=3, eta=1e-3, eta_g=2.0)
    round_fn = jax.jit(A.make_round_fn(cfg, reg, grad_fn))
    state = A.init_state(params0, 8)
    rng = np.random.default_rng(1)
    # warm-up full round so corrections are non-zero
    state, _ = round_fn(state, make_round_batches(data, 3, None, rng))
    active = jnp.asarray([True, False] * 4)
    before = jax.tree_util.tree_map(lambda x: np.asarray(x[1::2]), state.c)
    state, _ = round_fn(state, make_round_batches(data, 3, None, rng), active)
    after = jax.tree_util.tree_map(lambda x: np.asarray(x[1::2]), state.c)
    for a, b in zip(jax.tree_util.tree_leaves(after),
                    jax.tree_util.tree_leaves(before)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# nuclear norm
# ---------------------------------------------------------------------------


def test_nuclear_prox_soft_thresholds_singular_values():
    rng = np.random.default_rng(0)
    u, _ = np.linalg.qr(rng.normal(size=(6, 6)))
    v, _ = np.linalg.qr(rng.normal(size=(5, 5)))
    s = np.array([3.0, 2.0, 1.0, 0.4, 0.1])
    x = jnp.asarray(u[:, :5] @ np.diag(s) @ v)
    reg = Nuclear(lam=1.0)
    p = np.asarray(reg.prox({"w": x}, 0.5)["w"])
    s_out = np.linalg.svd(p, compute_uv=False)
    np.testing.assert_allclose(
        sorted(s_out, reverse=True), [2.5, 1.5, 0.5, 0.0, 0.0], atol=1e-5)
    # value
    val = float(reg.value({"w": x}))
    np.testing.assert_allclose(val, s.sum(), rtol=1e-5)


def test_nuclear_prox_nonexpansive_and_rank_reducing():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 8)))
    y = jnp.asarray(rng.normal(size=(8, 8)))
    reg = Nuclear(lam=0.5)
    px = np.asarray(reg.prox({"w": x}, 1.0)["w"])
    py = np.asarray(reg.prox({"w": y}, 1.0)["w"])
    assert np.linalg.norm(px - py) <= np.linalg.norm(np.asarray(x - y)) + 1e-9
    # large eta collapses rank
    p_big = np.asarray(reg.prox({"w": x}, 20.0)["w"])
    assert np.linalg.matrix_rank(p_big, tol=1e-6) < np.linalg.matrix_rank(
        np.asarray(x))


def test_federated_low_rank_matrix_sensing():
    """End-to-end: Algorithm 1 with the nuclear regularizer recovers a
    low-rank matrix from heterogeneous linear measurements."""
    rng = np.random.default_rng(2)
    m, n, r = 8, 8, 2
    true = (rng.normal(size=(m, r)) @ rng.normal(size=(r, n))).astype(
        np.float64)
    n_clients, meas = 4, 60

    # client i measures <A_k, X> with client-specific measurement statistics
    As, ys = [], []
    for i in range(n_clients):
        scale = 0.5 + i * 0.5  # heterogeneous sensing distributions
        A_i = rng.normal(scale=scale, size=(meas, m, n))
        As.append(A_i)
        ys.append(np.einsum("kmn,mn->k", A_i, true))
    As, ys = np.stack(As), np.stack(ys)

    def grad_fn(params, batch):
        X = params["X"]
        resid = jnp.einsum("kmn,mn->k", batch["A"], X) - batch["y"]
        loss = 0.5 * jnp.mean(resid ** 2)
        g = jnp.einsum("k,kmn->mn", resid, batch["A"]) / batch["y"].shape[0]
        return loss, {"X": g}

    reg = Nuclear(lam=0.02)
    cfg = A.DProxConfig(tau=4, eta=5e-3, eta_g=2.0)
    round_fn = jax.jit(A.make_round_fn(cfg, reg, grad_fn))
    state = A.init_state({"X": jnp.zeros((m, n))}, n_clients)
    batches = {
        "A": jnp.asarray(np.broadcast_to(As[:, None], (n_clients, 4, meas, m, n))),
        "y": jnp.asarray(np.broadcast_to(ys[:, None], (n_clients, 4, meas))),
    }
    for _ in range(1000):
        state, _ = round_fn(state, batches)
    X_hat = np.asarray(A.global_params(reg, cfg, state)["X"])
    rel = np.linalg.norm(X_hat - true) / np.linalg.norm(true)
    assert rel < 0.02, f"low-rank recovery failed: rel err {rel:.3f}"
    assert np.linalg.matrix_rank(X_hat, tol=1e-2) <= r + 2
