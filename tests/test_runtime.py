"""Multi-process runtime tests: the bitwise parity pin and the protocol.

The load-bearing acceptance pin: a server process + worker exchanging real
frames over a localhost socket produces a server trajectory BITWISE
identical to the single-process engine, for the dense transport and for a
ratio-1.0 top-k (whose compressed output equals its input exactly), in
both blocking and overlapped modes, per-leaf and plane layouts.

The server runs on a background thread in-process (same socket machinery
as the subprocess path -- ``run_pair`` drives the true 2-process form, and
the CI bench-smoke job runs ``--role pair --check-parity`` as separate OS
processes); the slow marker keeps the full subprocess variant out of the
fast CI leg.
"""
import os
import sys
import threading
import traceback

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

from repro.fed.runtime import (RuntimeArgs, _fields_bitwise, run_local,
                               run_pair, run_server, run_worker,
                               shard_bounds)


def _base_args(**kw) -> RuntimeArgs:
    defaults = dict(clients=8, m=16, dim=24, tau=2, rounds=8, chunk=4,
                    workers=1, mode="blocking", timeout=60.0)
    defaults.update(kw)
    return RuntimeArgs(**defaults)


def _run_threaded(a: RuntimeArgs):
    """Server on a thread + ranks on threads (rank 0 inline): same sockets
    and frames as the subprocess form, with in-test error propagation."""
    box, errs = {}, []
    ready = threading.Event()

    def srv():
        try:
            box["server"] = run_server(
                a, ready_cb=lambda p: (box.update(port=p), ready.set()))
        except BaseException:
            errs.append(traceback.format_exc())
            ready.set()

    st = threading.Thread(target=srv, daemon=True)
    st.start()
    assert ready.wait(30), "server never bound"
    assert "port" in box, f"server failed: {errs}"
    a.port = box["port"]

    wthreads = []
    for rank in range(1, a.workers):
        def wrk(r=rank):
            try:
                box[f"worker{r}"] = run_worker(a, rank=r)
            except BaseException:
                errs.append(traceback.format_exc())

        t = threading.Thread(target=wrk, daemon=True)
        t.start()
        wthreads.append(t)
    box["worker0"] = run_worker(a, rank=0)
    for t in wthreads:
        t.join(60)
    st.join(60)
    assert not errs, f"runtime thread failed: {errs}"
    return box


class TestShardBounds:
    def test_even(self):
        assert shard_bounds(8, 2) == [(0, 4), (4, 8)]

    def test_remainder_spread(self):
        assert shard_bounds(10, 3) == [(0, 4), (4, 7), (7, 10)]

    def test_single(self):
        assert shard_bounds(5, 1) == [(0, 5)]


@pytest.mark.parametrize("mode", ["blocking", "overlapped"])
@pytest.mark.parametrize("transport,kw", [
    ("dense", {}),
    ("topk", {"ratio": 1.0}),
])
def test_two_process_bitwise_parity(mode, transport, kw):
    """THE acceptance pin: server state == single-process engine, bit for
    bit, dense and ratio-1.0 transports, both send modes."""
    a = _base_args(mode=mode, transport=transport, **kw)
    box = _run_threaded(a)
    local = run_local(_base_args(mode=mode, transport=transport, **kw))
    assert _fields_bitwise(local["fields"], box["server"]["fields"])
    # and the replay (zero client aux) tracked the worker's own commit
    assert box["server"]["max_replay_drift"] == 0.0


def test_plane_layout_parity():
    """Plane mode: the uplink crosses as ONE flat buffer per chunk and the
    pin still holds."""
    a = _base_args(plane=True, mode="overlapped")
    box = _run_threaded(a)
    local = run_local(_base_args(plane=True, mode="overlapped"))
    assert _fields_bitwise(local["fields"], box["server"]["fields"])


def test_compressed_transport_parity_and_byte_savings():
    """Top-k at low ratio: the worker's server fields (its own committed
    trajectory) install verbatim -- still bitwise vs local -- and the
    sparse wire frames are measurably smaller than dense ones."""
    dense = _run_threaded(_base_args(mode="blocking"))
    topk = _run_threaded(_base_args(mode="blocking", transport="topk",
                                    ratio=0.1))
    local = run_local(_base_args(transport="topk", ratio=0.1))
    assert _fields_bitwise(local["fields"], topk["server"]["fields"])
    assert (topk["worker0"]["bytes_sent"]
            < 0.7 * dense["worker0"]["bytes_sent"])


def test_quantize_palette_parity():
    a = _base_args(transport="quantize", bits=4, mode="overlapped")
    box = _run_threaded(a)
    local = run_local(_base_args(transport="quantize", bits=4,
                                 mode="overlapped"))
    assert _fields_bitwise(local["fields"], box["server"]["fields"])


def test_arrival_ledger_records_real_arrivals():
    a = _base_args(rounds=8, chunk=2)  # 4 chunks -> 4 arrivals
    box = _run_threaded(a)
    led = box["server"]["ledger"]
    assert led["arrivals"] == 4
    assert led["workers"] == 1
    assert led["bytes"] == box["worker0"]["bytes_sent"]
    assert box["server"]["version"] == 4
    # blocking mode ACKs each chunk before the next computes: age 0
    assert led["max_age"] == 0
    assert np.asarray(box["server"]["age_histogram"]).sum() == 4


def test_two_workers_fedbuff_converges():
    """N=2 is the chunk-FedBuff semantics (documented as non-bitwise):
    both shards commit, every arrival lands in the ledger, and the mixed
    server fields stay finite and move from init."""
    a = _base_args(workers=2, rounds=8, chunk=4, mode="overlapped")
    box = _run_threaded(a)
    res = box["server"]
    assert res["ledger"]["workers"] == 2
    assert res["version"] == 4  # 2 workers x 2 chunks
    w = np.asarray(res["fields"]["x_bar"]["w"])
    assert np.all(np.isfinite(w)) and np.abs(w).max() > 0


def test_overlapped_matches_blocking_bitwise():
    """The overlap pipeline changes WHEN bytes move, never WHAT commits."""
    b = _run_threaded(_base_args(mode="blocking"))
    o = _run_threaded(_base_args(mode="overlapped"))
    assert _fields_bitwise(b["server"]["fields"], o["server"]["fields"])


def test_worker_report_accounting():
    a = _base_args(mode="blocking")
    box = _run_threaded(a)
    rep = box["worker0"]
    assert rep["chunks"] == 2
    assert rep["bytes_sent"] > 0
    assert rep["send_wait_s"] >= 0.0
    assert rep["rounds"] == a.rounds
    assert "train_loss" in rep["metrics"]


@pytest.mark.slow
def test_true_subprocess_pair_parity():
    """The real thing: one server OS process + one worker OS process
    (rank 0 in this process), bitwise vs single-process."""
    a = _base_args(mode="overlapped")
    rep = run_pair(a)
    local = run_local(_base_args(mode="overlapped"))
    assert _fields_bitwise(local["fields"], rep["server_result"]["fields"])
    assert rep["server_result"]["max_replay_drift"] == 0.0


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
