"""The flat parameter plane (repro.core.plane) and everything built on it.

Pins the contracts of the flat-plane refactor:

  * ``flatten``/``unflatten`` round-trip bitwise for arbitrary
    shapes/dtypes/batch axes (property tests via tests/_hypo.py), padding
    is zero-filled and tile-aligned, and mixed-dtype trees fail loudly;
  * the plane-backed engine (``EngineConfig(plane=True)``) is BITWISE the
    per-leaf engine for every stage combination -- split-inline (dense
    uplink), placed, compressed, async, queued, downlink -- including
    non-identity leaf-granularity compressors (the plane path routes them
    through views);
  * ``granularity="global"`` compresses the whole d-vector: ratio 1.0 is
    the identity, global top-k beats per-leaf top-k at equal k on messages
    whose energy concentrates in one leaf, index/scale bytes are accounted
    once, and error feedback still telescopes;
  * the new plane Pallas kernels (threshold-select, quantize, weighted
    commit) match their repro.kernels.ref oracles in interpret mode, and
    the plane-flattened ``ops.fused_local_update`` is bitwise its per-leaf
    fallback;
  * the queue-aware two-stream clock: ``upload=None`` preserves the
    single-stream draws bitwise, ``upload=0.0`` preserves the trajectory,
    and a positive upload stream serializes uploads FIFO under the
    multi-slot queue.
"""
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or fixed-grid fallback

from repro.comm import (Dense, PlaneTransport, Quantize, RandK, TopK,
                        uplink_message_spec)
from repro.core import algorithm as A
from repro.core import plane as pln
from repro.core.prox import L1
from repro.data.synthetic import logistic_heterogeneous
from repro.exec import ArraySupplier, EngineConfig, RoundEngine
from repro.fed.simulator import DProxAlgorithm
from repro.kernels import ops, ref
from repro.models import logreg
from repro.sched import (DeterministicClock, LogNormalClock, Staleness,
                         StragglerClock, clock_is_stochastic)


# ---------------------------------------------------------------------------
# SegmentSpec + flatten/unflatten
# ---------------------------------------------------------------------------


def test_lanes_matches_kernel_package():
    from repro.kernels import fused_prox

    assert pln.LANES == fused_prox.LANES


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 4000),
       m=st.integers(1, 7), batch=st.integers(1, 5))
@settings(max_examples=15, deadline=None)
def test_flatten_roundtrip_bitwise(seed, n, m, batch):
    rng = np.random.default_rng(seed)
    tree = {
        "w": jnp.asarray(rng.normal(size=(batch, n)), jnp.float64),
        "b": jnp.asarray(rng.normal(size=(batch,)), jnp.float64),
        "k": jnp.asarray(rng.normal(size=(batch, m, 3)), jnp.float64),
    }
    spec = pln.SegmentSpec.from_tree(tree, batch_dims=1)
    flat = pln.flatten(spec, tree)
    assert flat.shape == (batch, spec.d_pad)
    assert spec.d == n + 1 + 3 * m
    assert spec.d_pad % pln.LANES == 0 and spec.d_pad >= spec.d
    # the padded tail is zero
    if spec.pad:
        np.testing.assert_array_equal(np.asarray(flat[:, spec.d:]), 0.0)
    back = pln.unflatten(spec, flat)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(back[k]))


@given(n=st.integers(1, 600), tile=st.sampled_from([1, 128, 1024, 32768]))
@settings(max_examples=10, deadline=None)
def test_spec_tile_alignment(n, tile):
    tree = {"w": jax.ShapeDtypeStruct((n,), jnp.float32)}
    spec = pln.SegmentSpec.from_tree(tree, tile=tile)
    assert spec.d == n
    assert spec.d_pad % tile == 0
    assert spec.d_pad - spec.d < tile


def test_spec_rejects_mixed_dtypes_and_bad_batch():
    with pytest.raises(ValueError, match="one dtype"):
        pln.SegmentSpec.from_tree({"a": jnp.zeros(3, jnp.float32),
                                   "b": jnp.zeros(3, jnp.float64)})
    with pytest.raises(ValueError, match="batch"):
        pln.SegmentSpec.from_tree({"a": jnp.zeros((2, 3), jnp.float32),
                                   "b": jnp.zeros((4, 3), jnp.float32)},
                                  batch_dims=1)
    with pytest.raises(ValueError, match="empty"):
        pln.SegmentSpec.from_tree({})


def test_param_plane_is_a_pytree():
    tree = {"w": jnp.arange(6, dtype=jnp.float32),
            "b": jnp.ones((), jnp.float32)}
    p = pln.ParamPlane.from_tree(tree)
    assert p.spec.d == 7
    # tree_map sees ONE contiguous leaf
    leaves = jax.tree_util.tree_leaves(p)
    assert len(leaves) == 1 and leaves[0].shape == (p.spec.d_pad,)
    doubled = jax.tree_util.tree_map(lambda x: 2 * x, p)
    np.testing.assert_array_equal(np.asarray(doubled.tree["w"]),
                                  2 * np.arange(6, dtype=np.float32))
    # jit-static spec: the plane crosses a jit boundary intact
    out = jax.jit(lambda q: q.with_data(q.data + 1))(p)
    np.testing.assert_array_equal(np.asarray(out.tree["b"]), 2.0)


# ---------------------------------------------------------------------------
# plane-backed engine == per-leaf engine, bitwise, per stage combination
# ---------------------------------------------------------------------------


def _problem(n=6, m=30, d=10, seed=0, lam=0.01):
    data = logistic_heterogeneous(
        n_clients=n, m_per_client=m, d=d, alpha=5, beta=5, seed=seed)
    s = np.linalg.norm(data.features.reshape(-1, d), axis=1).max()
    data.features = (data.features / s).astype(np.float64)
    data.labels = data.labels.astype(np.float64)
    reg = L1(lam=lam)
    grad_fn = logreg.make_grad_fn()
    params0 = {"w": jnp.zeros(d, jnp.float64), "b": jnp.zeros((), jnp.float64)}
    return data, reg, grad_fn, params0


def _dprox(reg, tau=3, eta=0.05, eta_g=2.0):
    return DProxAlgorithm(reg, A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g))


def _run(cfg, data, reg, grad_fn, params0, rounds=8, sup_seed=3):
    alg = _dprox(reg)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=sup_seed)
    eng = RoundEngine(alg, grad_fn, data.n_clients, cfg)
    state = eng.init(params0)
    state, metrics = eng.run(state, sup, rounds, seed=0)
    return eng, state, metrics


def _assert_states_equal(a, b, exact=True):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-12, atol=1e-15)


# combos marked exact=False involve the stochastic quantizer, whose
# dequantize (q / levels * s) feeds the EF subtraction: XLA contracts that
# multiply-subtract into an FMA differently across the two carry layouts,
# an ulp-level reassociation the bitwise pin cannot survive.  Everything
# the acceptance contract names (Dense / ratio-1.0 / top-k / rand-k select
# paths) is FMA-free and pinned EXACTLY.
STAGE_COMBOS = {
    # "inline" split form: dense uplink, no compression
    "split_inline": (dict(chunk_rounds=3, transport=Dense()), True),
    "compressed_topk": (dict(chunk_rounds=3, transport=TopK(ratio=0.5)),
                        True),
    "compressed_randk": (dict(chunk_rounds=2, transport=RandK(ratio=0.5)),
                         True),
    "compressed_quantize": (dict(chunk_rounds=2, transport=Quantize(bits=8)),
                            False),
    "async": (dict(chunk_rounds=2,
                   clock=StragglerClock(slowdown=4.0, jitter=0.0),
                   buffer_size=3, staleness=Staleness("poly", correct=True)),
              True),
    "queued": (dict(chunk_rounds=2,
                    clock=StragglerClock(slowdown=4.0, jitter=0.0),
                    buffer_size=3, queue_depth=2, transport=TopK(ratio=0.5),
                    staleness=Staleness("poly", correct=True)), True),
    "downlink": (dict(chunk_rounds=2, transport=TopK(ratio=0.5),
                      downlink=TopK(ratio=0.5)), True),
    "async_downlink": (dict(chunk_rounds=2, transport=TopK(ratio=0.5),
                            downlink=Dense(),
                            clock=StragglerClock(slowdown=4.0, jitter=0.0),
                            buffer_size=3), True),
}


@pytest.mark.parametrize("combo", sorted(STAGE_COMBOS))
def test_plane_engine_matches_per_leaf_bitwise(combo):
    data, reg, grad_fn, params0 = _problem(seed=1)
    kw, exact = STAGE_COMBOS[combo]
    _, s_leaf, m_leaf = _run(EngineConfig(**kw), data, reg, grad_fn, params0)
    eng, s_pl, m_pl = _run(EngineConfig(plane=True, **kw), data, reg,
                           grad_fn, params0)
    assert eng._plane_spec is not None and eng._plane_spec.d == 11
    _assert_states_equal(s_leaf, s_pl, exact=exact)
    if exact:
        np.testing.assert_array_equal(m_leaf["train_loss"],
                                      m_pl["train_loss"])
    else:
        np.testing.assert_allclose(m_leaf["train_loss"], m_pl["train_loss"],
                                   rtol=1e-12)
    if "vtime" in m_leaf:
        np.testing.assert_array_equal(m_leaf["vtime"], m_pl["vtime"])


def test_plane_engine_matches_per_leaf_placed():
    """Placement on top: flat carries get the 1-axis client placement."""
    from repro.launch.mesh import make_mesh_compat

    data, reg, grad_fn, params0 = _problem(seed=2)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    kw = dict(chunk_rounds=2, mesh=mesh,
              param_specs={"w": ("mlp",), "b": ()},
              transport=TopK(ratio=0.5),
              clock=StragglerClock(slowdown=4.0, jitter=0.0), buffer_size=3)
    _, s_leaf, m_leaf = _run(EngineConfig(**kw), data, reg, grad_fn, params0)
    _, s_pl, m_pl = _run(EngineConfig(plane=True, **kw), data, reg, grad_fn,
                         params0)
    _assert_states_equal(s_leaf, s_pl)
    np.testing.assert_array_equal(m_leaf["train_loss"], m_pl["train_loss"])


def test_plane_carry_is_flat():
    """The carry actually holds planes, not pytrees: one (n, d_pad) comm
    residual and (depth, n, d_pad) queued report buffers."""
    data, reg, grad_fn, params0 = _problem(seed=3)
    eng, _, _ = _run(
        EngineConfig(plane=True, chunk_rounds=2, transport=TopK(ratio=0.5),
                     clock=StragglerClock(slowdown=4.0), buffer_size=3,
                     queue_depth=2, staleness=Staleness("poly", correct=True)),
        data, reg, grad_fn, params0)
    d_pad = eng._plane_spec.d_pad
    assert d_pad % pln.LANES == 0
    assert eng._comm_state.shape == (6, d_pad)
    assert eng._sched_state.pending_msg.shape == (2, 6, d_pad)
    assert eng._sched_state.resid.shape == (6, d_pad)
    # wire accounting is layout-independent
    assert eng.uplink_bytes_per_client_round == 6 * (8 + 4)


def test_plane_rejects_protocol_and_eager():
    with pytest.raises(ValueError, match="protocol"):
        EngineConfig(plane=True, protocol=True).validate()
    with pytest.raises(ValueError, match="jit"):
        EngineConfig(plane=True, jit=False).validate()


def test_plane_step_matches_run_chunking():
    """plane mode composes with step()/chunk invariance."""
    data, reg, grad_fn, params0 = _problem(seed=4)
    states = []
    for ch in (1, 4):
        _, s, _ = _run(EngineConfig(plane=True, chunk_rounds=ch,
                                    transport=TopK(ratio=0.5)),
                       data, reg, grad_fn, params0, rounds=8)
        states.append(s)
    _assert_states_equal(states[0], states[1])


# ---------------------------------------------------------------------------
# global granularity
# ---------------------------------------------------------------------------


def test_global_topk_ratio_one_is_identity():
    data, reg, grad_fn, params0 = _problem(seed=5)
    kw = dict(chunk_rounds=3)
    _, s_d, m_d = _run(EngineConfig(transport=Dense(), **kw), data, reg,
                       grad_fn, params0)
    for plane in (False, True):
        _, s_g, m_g = _run(
            EngineConfig(transport=TopK(ratio=1.0, granularity="global"),
                         plane=plane, **kw), data, reg, grad_fn, params0)
        _assert_states_equal(s_d, s_g)
        np.testing.assert_array_equal(m_d["train_loss"], m_g["train_loss"])


def test_global_topk_selects_globally():
    """Per-leaf top-k keeps k coordinates in EVERY leaf; global top-k
    spends the whole budget where the energy is."""
    key = jax.random.PRNGKey(0)
    msg = {"big": jnp.asarray([[10.0, 9.0, 8.0, 7.0]]),
           "small": jnp.asarray([[0.1, 0.2]])}
    leaf = TopK(ratio=0.5).apply(msg, key)
    glob = TopK(ratio=0.5, granularity="global").apply(msg, key)
    # leaf: 2 of 4 kept in "big", 1 of 2 kept in "small"
    assert int((np.asarray(leaf["big"]) != 0).sum()) == 2
    assert int((np.asarray(leaf["small"]) != 0).sum()) == 1
    # global: k = round(0.5 * 6) = 3, all spent on "big"
    assert int((np.asarray(glob["big"]) != 0).sum()) == 3
    assert int((np.asarray(glob["small"]) != 0).sum()) == 0


def test_global_topk_recovers_more_energy():
    """At equal k-budget, global selection retains at least the per-leaf
    energy (strictly more on energy-concentrated messages)."""
    rng = np.random.default_rng(0)
    msg = {"a": jnp.asarray(rng.normal(size=(4, 50)) * 10),
           "b": jnp.asarray(rng.normal(size=(4, 50)) * 0.01)}
    key = jax.random.PRNGKey(1)
    leaf = TopK(ratio=0.3).apply(msg, key)
    glob = TopK(ratio=0.3, granularity="global").apply(msg, key)

    def energy(m):
        return sum(float(jnp.sum(v ** 2)) for v in m.values())

    assert energy(glob) > energy(leaf)


def test_global_uplink_bytes_accounted_once():
    spec = {"w": jax.ShapeDtypeStruct((4, 100), jnp.float32),
            "b": jax.ShapeDtypeStruct((4, 50), jnp.float32),
            "c": jax.ShapeDtypeStruct((4, 6), jnp.float32)}
    d = 156
    # top-k: one index stream for the global k
    k_g = max(1, round(0.1 * d))
    assert (TopK(ratio=0.1, granularity="global").uplink_bytes(spec)
            == k_g * (4 + 4))
    # per-leaf pays ceil-ed k per leaf
    assert (TopK(ratio=0.1).uplink_bytes(spec)
            == (10 + 5 + 1) * (4 + 4))
    # quantize: ONE scale instead of one per leaf (and one contiguous bit
    # packing instead of per-leaf round-up)
    q_leaf = Quantize(bits=8).uplink_bytes(spec)
    q_glob = Quantize(bits=8, granularity="global").uplink_bytes(spec)
    assert q_leaf - q_glob >= 2 * 4  # at least the two saved fp32 scales
    with pytest.raises(ValueError, match="granularity"):
        TopK(granularity="warp")
    with pytest.raises(ValueError, match="single-dtype"):
        TopK(granularity="global").uplink_bytes(
            {"a": jax.ShapeDtypeStruct((4, 3), jnp.float32),
             "b": jax.ShapeDtypeStruct((4, 3), jnp.float64)})


def test_global_error_feedback_telescopes():
    """sum of transmitted == sum of produced - final residual, globally."""
    rng = np.random.default_rng(2)
    tr = TopK(ratio=0.3, granularity="global")
    msgs = [{"a": jnp.asarray(rng.normal(size=(3, 20))),
             "b": jnp.asarray(rng.normal(size=(3, 5)))} for _ in range(6)]
    cs = tr.init_state(msgs[0])
    key = jax.random.PRNGKey(0)
    sent_sum = jax.tree_util.tree_map(jnp.zeros_like, msgs[0])
    for m in msgs:
        hat, cs = tr.compress(cs, m, key)
        sent_sum = jax.tree_util.tree_map(jnp.add, sent_sum, hat)
    produced = jax.tree_util.tree_map(
        lambda *xs: sum(xs), *msgs)
    for k in sent_sum:
        np.testing.assert_allclose(
            np.asarray(sent_sum[k]),
            np.asarray(produced[k]) - np.asarray(cs[k]),
            atol=1e-12)


def test_global_quantize_and_randk_train():
    data, reg, grad_fn, params0 = _problem(seed=6)
    for tr in (Quantize(bits=6, granularity="global"),
               RandK(ratio=0.5, granularity="global")):
        for plane in (False, True):
            eng, s, m = _run(EngineConfig(transport=tr, plane=plane,
                                          chunk_rounds=2),
                             data, reg, grad_fn, params0, rounds=10)
            assert np.isfinite(m["train_loss"]).all()
        # plane and pytree layouts draw identically -> same trajectory
        # (up to the FMA-contraction ulps noted at STAGE_COMBOS)
        _, s_t, m_t = _run(EngineConfig(transport=tr, chunk_rounds=2),
                           data, reg, grad_fn, params0, rounds=10)
        _, s_p, m_p = _run(EngineConfig(transport=tr, plane=True,
                                        chunk_rounds=2),
                           data, reg, grad_fn, params0, rounds=10)
        _assert_states_equal(s_t, s_p, exact=False)


def test_plane_transport_compress_matches_pytree_compress():
    rng = np.random.default_rng(3)
    msg = {"w": jnp.asarray(rng.normal(size=(4, 10))),
           "b": jnp.asarray(rng.normal(size=(4,)))}
    spec = pln.SegmentSpec.from_tree(msg, batch_dims=1)
    for tr in (TopK(ratio=0.5), TopK(ratio=0.4, granularity="global"),
               Quantize(bits=8), Dense()):
        pt = PlaneTransport(tr, spec)
        key = jax.random.PRNGKey(0)
        cs_t = tr.init_state(msg)
        cs_f = pt.init_state(
            jax.ShapeDtypeStruct((4, spec.d_pad), spec.dtype))
        flat = pln.flatten(spec, msg)
        hat_t, cs_t = tr.compress(cs_t, msg, key)
        hat_f, cs_f = pt.compress(cs_f, flat, key)
        _assert_states_equal(hat_t, pln.unflatten(spec, hat_f))
        if tr.error_feedback:
            _assert_states_equal(cs_t, pln.unflatten(spec, cs_f))
            # the EF plane's padded tail stays zero (donation-safe algebra)
            np.testing.assert_array_equal(
                np.asarray(cs_f[:, spec.d:]), 0.0)


# ---------------------------------------------------------------------------
# plane Pallas kernels vs the jnp oracles (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(3, 128), (5, 512), (2, 1024)])
def test_threshold_select_kernel_matches_ref(shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    th = jnp.asarray(np.abs(rng.normal(size=shape[0])), jnp.float32)
    got = ops.plane_threshold_select(x, th, interpret=True, block_rows=2)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.plane_threshold_select(x, th)))


@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_kernel_matches_ref(bits):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 640)), jnp.float32)
    u = jnp.asarray(rng.uniform(size=(4, 640)), jnp.float32)
    s = jnp.max(jnp.abs(x), axis=1)
    levels = (1 << bits) - 1
    got = ops.plane_quantize(x, u, s, levels, interpret=True, block_rows=1)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.plane_quantize(x, u, s, levels)),
        atol=1e-6)
    # zero rows quantize to zero (scale guard)
    z = jnp.zeros((2, 256), jnp.float32)
    got = ops.plane_quantize(z, u[:2, :256], jnp.zeros(2), levels,
                             interpret=True, block_rows=1)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_weighted_commit_kernel_matches_ref():
    rng = np.random.default_rng(2)
    buf = jnp.asarray(rng.normal(size=(6, 512)), jnp.float32)
    w = jnp.asarray(rng.uniform(size=6), jnp.float32)
    got = ops.plane_weighted_commit(buf, w, interpret=True, block_rows=2)
    # the kernel accumulates sequentially in fp32; jnp.sum may reduce in a
    # different order -- 1-ulp tolerance
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.plane_weighted_commit(buf, w)),
        rtol=1e-5, atol=1e-6)


def test_fused_local_update_plane_matches_per_leaf():
    """The plane-flattened fused update == the per-leaf fallback bitwise
    (same kernel arithmetic, one launch instead of N)."""
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.normal(size=(900,)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(3, 7)), jnp.float32)}
    g = jax.tree_util.tree_map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), tree)
    c = jax.tree_util.tree_map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), tree)
    got = ops.fused_local_update(tree, g, c, 0.05, 0.01, interpret=True,
                                 block_rows=8)
    exp = ops._fused_local_update_per_leaf(tree, g, c, 0.05, 0.01,
                                           interpret=True, block_rows=8)
    for a, b in zip(got, exp):
        _assert_states_equal(a, b)
    # mixed-dtype trees take the per-leaf fallback instead of failing
    mixed = {"w": jnp.zeros((40,), jnp.float32),
             "b": jnp.zeros((2,), jnp.bfloat16)}
    zh, z = ops.fused_local_update(mixed, mixed, mixed, 0.05, 0.01,
                                   interpret=True, block_rows=8)
    assert zh["b"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# queue-aware two-stream clocks
# ---------------------------------------------------------------------------


def test_upload_none_preserves_single_stream_draws_bitwise():
    key = jax.random.PRNGKey(7)
    for clock in (LogNormalClock(sigma=0.7), StragglerClock(),
                  DeterministicClock(duration=2.0)):
        comp, upl = clock.split_durations(key, jnp.int32(0), 8)
        np.testing.assert_array_equal(
            np.asarray(comp), np.asarray(clock.durations(key, jnp.int32(0), 8)))
        np.testing.assert_array_equal(np.asarray(upl), 0.0)


def test_upload_zero_trajectory_bitwise():
    data, reg, grad_fn, params0 = _problem(seed=7)
    base = dict(chunk_rounds=2, buffer_size=3, queue_depth=2,
                staleness=Staleness("poly", correct=True))
    _, s0, m0 = _run(EngineConfig(clock=StragglerClock(jitter=0.0), **base),
                     data, reg, grad_fn, params0)
    _, s1, m1 = _run(
        EngineConfig(clock=StragglerClock(jitter=0.0, upload=0.0), **base),
        data, reg, grad_fn, params0)
    _assert_states_equal(s0, s1)
    np.testing.assert_array_equal(m0["vtime"], m1["vtime"])


def test_deterministic_upload_keeps_compute_draws():
    """A constant upload stream must not perturb the compute draws (no key
    split for a keyless consumer)."""
    key = jax.random.PRNGKey(3)
    plain = LogNormalClock(sigma=0.5)
    with_up = LogNormalClock(sigma=0.5, upload=2.5)
    c0, _ = plain.split_durations(key, jnp.int32(0), 6)
    c1, u1 = with_up.split_durations(key, jnp.int32(0), 6)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    np.testing.assert_array_equal(np.asarray(u1), 2.5)
    # a stochastic upload clock splits (and is flagged stochastic)
    both = LogNormalClock(sigma=0.5, upload=LogNormalClock(sigma=0.1))
    c2, u2 = both.split_durations(key, jnp.int32(0), 6)
    assert not np.array_equal(np.asarray(c0), np.asarray(c2))
    assert clock_is_stochastic(both)
    assert clock_is_stochastic(
        DeterministicClock(upload=LogNormalClock()))
    assert not clock_is_stochastic(DeterministicClock(upload=1.0))


def test_upload_serializes_fifo_under_queue():
    """Fast compute + slow upload: a queued client's arrivals are spaced by
    the upload time (upload-bandwidth-limited), not the compute time."""
    data, reg, grad_fn, params0 = _problem(seed=8)
    eng, state, m = _run(
        EngineConfig(chunk_rounds=2,
                     clock=DeterministicClock(duration=0.1, upload=5.0),
                     buffer_size=3, queue_depth=3),
        data, reg, grad_fn, params0, rounds=10)
    assert np.isfinite(m["train_loss"]).all()
    # in-flight uploads of one client are spaced >= the upload time
    dt = np.asarray(eng._sched_state.deliver_time)
    filled = np.asarray(eng._sched_state.slot_filled)
    for cidx in range(data.n_clients):
        times = np.sort(dt[filled[:, cidx], cidx])
        if len(times) > 1:
            assert (np.diff(times) >= 5.0 - 1e-5).all()
    # and virtual time reflects uploads, not the 0.1 compute
    assert m["vtime"][-1] >= 5.0


def test_duck_typed_clock_still_runs():
    """Clocks that implement only ``durations`` (no ClockModel subclass, no
    upload/stochastic/split_durations surface) keep working: the aggregator
    falls back to the single-stream zero-upload form."""

    class DuckClock:
        name = "duck"

        def durations(self, key, round_idx, n_clients):
            return jnp.full((n_clients,), 2.0, jnp.float32)

    assert clock_is_stochastic(DuckClock())  # assumed stochastic
    data, reg, grad_fn, params0 = _problem(seed=10)
    eng, state, m = _run(
        EngineConfig(chunk_rounds=2, clock=DuckClock(), buffer_size=3),
        data, reg, grad_fn, params0, rounds=6)
    assert np.isfinite(m["train_loss"]).all()
    # half-buffer commits arrive in waves of the fixed 2.0 duration
    np.testing.assert_allclose(np.asarray(m["vtime"]),
                               [2.0, 2.0, 4.0, 4.0, 6.0, 6.0])


def test_upload_increases_vtime_one_slot():
    data, reg, grad_fn, params0 = _problem(seed=9)
    base = dict(chunk_rounds=2, buffer_size=6)
    _, _, m0 = _run(EngineConfig(clock=DeterministicClock(duration=1.0),
                                 **base), data, reg, grad_fn, params0,
                    rounds=6)
    _, _, m1 = _run(
        EngineConfig(clock=DeterministicClock(duration=1.0, upload=2.0),
                     **base), data, reg, grad_fn, params0, rounds=6)
    np.testing.assert_allclose(np.asarray(m1["vtime"]),
                               3.0 * np.asarray(m0["vtime"]))
