"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures, instantiate the REDUCED variant of
the same family (<=2-3 layers, d_model<=512, <=4 experts) and run one forward
pass AND one federated train round (Algorithm 1) on CPU, asserting output
shapes and the absence of NaNs.  Decode-capable archs also run one prefill +
one decode step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES, InputShape
from repro.core.algorithm import DProxConfig, init_state, make_round_fn
from repro.core.prox import L1
from repro.launch import specs
from repro.models import transformer as T
from repro.utils import tree as tu

ARCHS = registry.ARCH_IDS

SMOKE_TRAIN = InputShape("smoke_train", "train", 64, 4)
SMOKE_DECODE = InputShape("smoke_decode", "decode", 64, 2)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def build(arch):
        if arch not in cache:
            cfg = registry.get_smoke(arch)
            params, spec = T.init_model(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params, spec)
        return cache[arch]

    return build


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, built):
    cfg, params, spec = built(arch)
    rng = np.random.default_rng(0)
    batch = specs._example(cfg, 2, 64, False, rng)
    logits, _, aux = T.forward(params, cfg, batch, mode="train")
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))
    loss = T.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"


@pytest.mark.parametrize("arch", ARCHS)
def test_spec_tree_mirrors_params(arch, built):
    cfg, params, spec = built(arch)
    pl = jax.tree_util.tree_leaves(params)
    is_spec = lambda x: isinstance(x, tuple) and all(isinstance(a, str) for a in x)
    sl = jax.tree_util.tree_leaves(spec, is_leaf=is_spec)
    assert len(pl) == len(sl), f"{arch}: spec tree mismatch"
    for a, s in zip(pl, sl):
        assert len(s) == a.ndim, f"{arch}: spec rank {s} vs array {a.shape}"


@pytest.mark.parametrize("arch", ARCHS)
def test_federated_train_round(arch, built):
    """One Algorithm-1 round over the reduced arch: shapes + no NaNs."""
    cfg, params, spec = built(arch)
    fcfg = DProxConfig(tau=2, eta=1e-3, eta_g=2.0)
    reg = L1(lam=1e-5)
    grad_fn = T.make_grad_fn(cfg)
    batches = specs.train_batches(cfg, SMOKE_TRAIN, n_clients=2, tau=2,
                                  abstract=False)
    state = init_state(params, 2)
    round_fn = jax.jit(make_round_fn(fcfg, reg, grad_fn))
    state, info = round_fn(state, batches)
    assert bool(jnp.isfinite(info["train_loss"])), f"{arch}: loss NaN"
    assert bool(tu.tree_isfinite(state.x_bar)), f"{arch}: x_bar has NaNs"
    assert bool(tu.tree_isfinite(state.c)), f"{arch}: corrections have NaNs"
    # shapes preserved
    for a, b in zip(jax.tree_util.tree_leaves(state.x_bar),
                    jax.tree_util.tree_leaves(params)):
        assert a.shape == b.shape


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get_smoke(a).decode_supported])
def test_prefill_and_decode_step(arch, built):
    cfg, params, spec = built(arch)
    rng = np.random.default_rng(1)
    batch = specs._example(cfg, 2, 32, False, rng)
    logits, caches, cache_len = T.prefill(params, cfg, batch, max_len=33)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    dec_logits, new_caches = T.decode_step(params, cfg, caches, tok, cache_len)
    assert dec_logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(dec_logits.astype(jnp.float32))))
    assert bool(tu.tree_isfinite(new_caches)), f"{arch}: cache NaN"


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if registry.get_smoke(a).decode_supported])
def test_decode_matches_full_forward(arch, built):
    """Cache-vs-recompute: greedy decode logits at position S must match the
    full-sequence forward logits at position S (teacher forcing)."""
    import dataclasses

    cfg, params, spec = built(arch)
    cfg = cfg.with_overrides(param_dtype=jnp.float32)
    if cfg.moe is not None:
        # lossless dispatch: capacity-dropping makes full-forward and decode
        # legitimately differ, which is not what this test measures
        lossless = dataclasses.replace(
            cfg.moe, capacity_factor=cfg.moe.num_experts / cfg.moe.top_k + 0.1)
        cfg = cfg.with_overrides(moe=lossless)
    params, _ = T.init_model(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    S = 24
    if cfg.frontend == "vision":
        full = specs._example(cfg, 1, S + 1, False, rng)
        pre = {"patches": full["patches"], "tokens": full["tokens"][:, :-1]}
        nxt = full["tokens"][:, -1:]
    else:
        full = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, size=(1, S + 1)), jnp.int32)}
        pre = {"tokens": full["tokens"][:, :-1]}
        nxt = full["tokens"][:, -1:]
    ref_logits, _, _ = T.forward(params, cfg, full, mode="train")
    _, caches, cache_len = T.prefill(params, cfg, pre, max_len=S + 1)
    dec_logits, _ = T.decode_step(params, cfg, caches, nxt, cache_len)
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(ref_logits[:, -1]),
        rtol=2e-3, atol=2e-3,
    )
