"""Correctness tests for Algorithm 1 (repro.core.algorithm).

These encode the paper's structural claims:
  * the compact form (Eq. 2) is equivalent to the per-client protocol (App. A.1);
  * correction terms average to zero: W C^r = 0 for all r (Eq. A.4);
  * at tau=1 the algorithm coincides with FedDA (no drift, same steps);
  * the (t+1)*eta prox schedule makes stationary points fixed points
    (Algorithm 2 / Appendix A.2);
  * with full gradients and local updates it converges to machine precision
    under heterogeneity while FedDA stalls (Fig. 2 right);
  * sparsity of the global model is preserved (vs FedMid's curse of primal
    averaging).
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithm as A
from repro.core.baselines import FedDA, FedMid
from repro.core.metrics import prox_gradient_norm, sparsity
from repro.core.prox import L1
from repro.data.synthetic import logistic_heterogeneous, make_round_batches
from repro.fed.simulator import DProxAlgorithm, run
from repro.models import logreg
from repro.utils import tree as tu


def _problem(n=8, m=40, d=10, seed=0, lam=0.003):
    data = logistic_heterogeneous(
        n_clients=n, m_per_client=m, d=d, alpha=5, beta=5, seed=seed
    )
    s = np.linalg.norm(data.features.reshape(-1, d), axis=1).max()
    data.features = (data.features / s).astype(np.float64)
    data.labels = data.labels.astype(np.float64)
    reg = L1(lam=lam)
    grad_fn = logreg.make_grad_fn()
    params0 = {"w": jnp.zeros(d, jnp.float64), "b": jnp.zeros((), jnp.float64)}
    return data, reg, grad_fn, params0


def _smoothness(data):
    d = data.features.shape[-1]
    Amat = data.features.reshape(-1, d)
    return float(np.linalg.eigvalsh(Amat.T @ Amat / (4 * Amat.shape[0]))[-1])


def test_compact_form_equals_per_client_protocol():
    """Appendix A.1: Eq. (2) == Algorithm 1 message passing, bit-for-bit-ish."""
    data, reg, grad_fn, params0 = _problem()
    cfg = A.DProxConfig(tau=4, eta=0.05, eta_g=2.0)
    rng = np.random.default_rng(1)
    state_c = A.init_state(params0, data.n_clients)
    state_p = A.init_state(params0, data.n_clients)
    round_fn = A.make_round_fn(cfg, reg, grad_fn)
    for r in range(3):
        batches = make_round_batches(data, cfg.tau, 16, rng)
        batches = jax.tree_util.tree_map(jnp.asarray, batches)
        state_c, _ = round_fn(state_c, batches)
        state_p = A.run_per_client_round(cfg, reg, grad_fn, state_p, batches)
        np.testing.assert_allclose(
            np.asarray(state_c.x_bar["w"]), np.asarray(state_p.x_bar["w"]),
            rtol=1e-12, atol=1e-12,
        )
        np.testing.assert_allclose(
            np.asarray(state_c.c["w"]), np.asarray(state_p.c["w"]),
            rtol=1e-10, atol=1e-12,
        )


def test_correction_terms_average_to_zero():
    """Eq. (A.4): W C^r = 0 for every round r."""
    data, reg, grad_fn, params0 = _problem(seed=3)
    cfg = A.DProxConfig(tau=5, eta=0.02, eta_g=3.0)
    rng = np.random.default_rng(0)
    state = A.init_state(params0, data.n_clients)
    round_fn = jax.jit(A.make_round_fn(cfg, reg, grad_fn))
    for r in range(5):
        batches = make_round_batches(data, cfg.tau, 8, rng)
        state, _ = round_fn(state, batches)
        mean_c = tu.tree_mean_over_axis0(state.c)
        assert float(tu.tree_norm(mean_c)) < 1e-12


def test_tau1_coincides_with_fedda():
    """At tau=1 there is no drift and ours == FedDA exactly (paper Fig. 2 left)."""
    data, reg, grad_fn, params0 = _problem(seed=2)
    tau, eta, eta_g = 1, 0.05, 3.0
    cfg = A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g)
    round_fn = jax.jit(A.make_round_fn(cfg, reg, grad_fn))
    da = FedDA(reg, tau, eta, eta_g)
    round_da = jax.jit(da.make_round_fn(grad_fn))
    s = A.init_state(params0, data.n_clients)
    s_da = da.init(params0, data.n_clients)
    rng = np.random.default_rng(0)
    for r in range(10):
        batches = make_round_batches(data, tau, None, rng)
        s, _ = round_fn(s, batches)
        s_da, _ = round_da(s_da, batches)
    np.testing.assert_allclose(
        np.asarray(s.x_bar["w"]), np.asarray(s_da.x_bar["w"]), rtol=0, atol=1e-12
    )


def test_stationary_point_is_fixed_point():
    """Algorithm 2 / Appendix A.2: with n=1 and full gradients, starting the
    round from x_bar = x* - eta_tilde * grad f(x*) keeps every iterate at x*.
    This is the property that motivates the (t+1)*eta prox schedule."""
    data, reg, grad_fn, params0 = _problem(n=1, m=60, seed=5)
    L = _smoothness(data)
    # find x* by long centralized prox-GD
    full_g = logreg.full_gradient_fn(data.features, data.labels)
    x = params0
    step = 1.0 / L

    @jax.jit
    def pgd(x):
        g = full_g(x)
        return reg.prox(
            jax.tree_util.tree_map(lambda xi, gi: xi - step * gi, x, g), step
        )

    for _ in range(8000):
        x = pgd(x)
    gnorm = float(prox_gradient_norm(reg, full_g, x, step))
    assert gnorm < 1e-12, f"PGD failed to find stationary point, ||G||={gnorm:.2e}"

    tau, eta_g = 4, 2.0
    eta = step / (eta_g * tau)
    cfg = A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g)
    # x_bar^1 = x* - eta_tilde * grad f(x*)  (Line 3 of Algorithm 2)
    g_star = full_g(x)
    x_bar = jax.tree_util.tree_map(
        lambda xi, gi: xi - cfg.eta_tilde * gi, x, g_star
    )
    state = A.DProxState(
        x_bar=x_bar,
        c=tu.tree_broadcast_axis0(tu.tree_zeros_like(x), 1),
        round=jnp.zeros((), jnp.int32),
    )
    round_fn = jax.jit(A.make_round_fn(cfg, reg, grad_fn))
    rng = np.random.default_rng(0)
    for r in range(5):
        batches = make_round_batches(data, tau, None, rng)
        state, _ = round_fn(state, batches)
        out = A.global_params(reg, cfg, state)
        err = float(
            tu.tree_norm(jax.tree_util.tree_map(lambda a, b: a - b, out, x))
        )
        assert err < 1e-10, f"round {r}: drifted {err:.2e} from stationary point"


@pytest.mark.slow
def test_full_gradient_converges_to_machine_precision_fedda_stalls():
    """Fig. 2 (right): tau=10, full gradients, heterogeneous data."""
    data, reg, grad_fn, params0 = _problem(n=10, m=60, d=12, seed=7)
    L = _smoothness(data)
    full_g = logreg.full_gradient_fn(data.features, data.labels)
    tau, eta_g = 10, 3.0
    eta_tilde = 0.5 / L
    eta = eta_tilde / (eta_g * tau)
    cfg = A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g)
    supplier = lambda r, rng: make_round_batches(data, tau, None, rng)
    h = run(
        DProxAlgorithm(reg, cfg), params0, grad_fn, supplier, 10, 4000,
        reg=reg, eta_tilde=eta_tilde, full_grad_fn=full_g, eval_every=4000,
    )
    h_da = run(
        FedDA(reg, tau, eta, eta_g), params0, grad_fn, supplier, 10, 4000,
        reg=reg, eta_tilde=eta_tilde, full_grad_fn=full_g, eval_every=4000,
    )
    # ours keeps converging (linear rate, Theorem 3.6); FedDA stalls at the
    # drift floor.  The 20k-round benchmark (benchmarks/fig2) reaches 1e-11.
    assert h.optimality[-1] < 1e-4, f"ours stalled at {h.optimality[-1]:.2e}"
    assert h_da.optimality[-1] > 10 * h.optimality[-1], (
        f"FedDA should stall above ours: {h_da.optimality[-1]:.2e} vs {h.optimality[-1]:.2e}"
    )


def test_sparsity_preserved_vs_fedmid():
    """The decoupling avoids the curse of primal averaging: the global model
    stays exactly sparse, while FedMid's averaged model is dense."""
    data, reg, grad_fn, params0 = _problem(n=8, m=40, d=16, seed=9, lam=0.05)
    L = _smoothness(data)
    tau, eta_g = 5, 3.0
    eta_tilde = 0.5 / L
    eta = eta_tilde / (eta_g * tau)
    cfg = A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g)
    supplier = lambda r, rng: make_round_batches(data, tau, None, rng)
    h = run(DProxAlgorithm(reg, cfg), params0, grad_fn, supplier, 8, 400)
    h_mid = run(FedMid(reg, tau, eta, eta_g), params0, grad_fn, supplier, 8, 400)
    ours_sp = float(sparsity(h.extra["final_params"]["w"]))
    mid_sp = float(sparsity(h_mid.extra["final_params"]["w"]))
    assert ours_sp > 0.3, f"expected sparse global model, got sparsity={ours_sp}"
    assert mid_sp < ours_sp, "FedMid should lose sparsity via primal averaging"


def test_drift_metric_decreases_with_correction():
    """The correction term should shrink client drift relative to FedDA-style
    uncorrected local updates (measured by the round_fn drift metric)."""
    data, reg, grad_fn, params0 = _problem(n=8, m=40, d=10, seed=11)
    L = _smoothness(data)
    tau, eta_g = 8, 3.0
    eta = (0.5 / L) / (eta_g * tau)
    cfg = A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g)
    round_fn = jax.jit(A.make_round_fn(cfg, reg, grad_fn))
    state = A.init_state(params0, data.n_clients)
    rng = np.random.default_rng(0)
    drifts = []
    for r in range(30):
        batches = make_round_batches(data, tau, None, rng)
        state, info = round_fn(state, batches)
        drifts.append(float(info["drift"]))
    # after warm-up rounds the corrected drift collapses
    assert drifts[-1] < 0.2 * drifts[0], f"drift did not shrink: {drifts[0]:.3e} -> {drifts[-1]:.3e}"
