"""Empirical validation of the roofline methodology's core assumptions:

  1. ``compiled.cost_analysis()`` on the forced-host backend reports
     PER-DEVICE, post-partitioning flops (2*M*N*K per dot);
  2. collectives appear in ``compiled.as_text()`` with per-shard shapes and
     parseable replica groups;
  3. the probe extrapolation is exact for a linear-in-depth model.

Runs in a subprocess with 8 forced host devices.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import make_mesh_compat
from repro.roofline.analysis import cost_analysis_dict
mesh = make_mesh_compat((4, 2), ("data", "model"))
B, S, H, D = 8, 256, 8, 64
sh = NamedSharding(mesh, P("data", None, "model", None))

def f(q, k):
    return jnp.einsum("bshd,bthd->bhst", q, k)

c = jax.jit(f, in_shardings=(sh, sh)).lower(
    jax.ShapeDtypeStruct((B, S, H, D), jnp.float32),
    jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)).compile()
flops = cost_analysis_dict(c)["flops"]
analytic_per_dev = 2 * B * S * S * H * D / 8
assert abs(flops / analytic_per_dev - 1) < 0.05, (flops, analytic_per_dev)

# 2: collectives parse from a program that must all-reduce
def g(x, w):
    return jnp.einsum("bd,df->bf", x, w)  # contraction dim sharded -> AR

xs = NamedSharding(mesh, P("data", "model"))
ws = NamedSharding(mesh, P("model", None))
c2 = jax.jit(g, in_shardings=(xs, ws)).lower(
    jax.ShapeDtypeStruct((16, 64), jnp.float32),
    jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
import sys
sys.path.insert(0, "src")
from repro.roofline.analysis import parse_collectives
colls = parse_collectives(c2.as_text())
assert any(op in ("all-reduce", "reduce-scatter") for op, *_ in colls), colls

# 3: probe extrapolation exact on a depth-linear scan model
def stack(depth):
    def fn(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws, unroll=True)
        return x
    return fn

def cost(depth):
    c = jax.jit(stack(depth)).lower(
        jax.ShapeDtypeStruct((32, 128), jnp.float32),
        jax.ShapeDtypeStruct((depth, 128, 128), jnp.float32)).compile()
    return cost_analysis_dict(c)["flops"]

f2, f3 = cost(2), cost(3)
C = f3 - f2
pred10 = f2 + 8 * C
assert abs(pred10 / cost(10) - 1) < 0.02, (pred10, cost(10))
print("ROOFLINE_OK")
"""


@pytest.mark.slow
def test_roofline_assumptions_hold():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "ROOFLINE_OK" in out.stdout
