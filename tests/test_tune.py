"""The closed-loop autotuner (repro.tune) + the staleness-adaptive
compression schedule (repro.comm.schedule).

Pins the contracts the tuner is only useful under:

  * tuning records round-trip through the per-host cache -- hostile
    signature content never escapes into the filename, provenance is
    stamped, corrupt/mismatched records never silently hit;
  * the search is deterministic in its seed: same (seed, budget, space,
    workload) -> the same measured-trial sequence, bit for bit;
  * a second invocation against a persisted record executes ZERO measured
    trials (the whole point of persisting them);
  * a CONSTANT ratio schedule is bitwise the fixed-ratio transport for
    the inline/topk/async/queued stage combinations -- the adaptive
    schedule is strictly opt-in;
  * the adaptive schedule spends fewer measured uplink bytes than
    constant on a straggler workload (the bytes it exists to save).
"""
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import logreg_problem, make_engine

from repro.comm import (RatioSchedule, ScheduledTopK, TopK, as_schedule,
                        scheduled_transport)
from repro.core.algorithm import DProxConfig
from repro.exec import ArraySupplier
from repro.fed.simulator import DProxAlgorithm
from repro.sched import Staleness, StragglerClock
from repro.tune import (SCHEMA, SearchSpace, TrialPoint, TrialRunner,
                        Workload, engine_config_kwargs, load_record,
                        record_key, record_path, save_record, tune,
                        validate_record)
from repro.tune.records import host_signature


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


class FakeRunner(TrialRunner):
    """Analytic runner: deterministic objective, counts measured trials."""

    def __init__(self, workload, rounds=16):
        super().__init__(workload, rounds=rounds)
        self.sequence = []

    def measure(self, point):
        from repro.obs.metrics import MetricsRegistry

        self.measured_trials += 1
        self.sequence.append((point.key(), self.rounds))
        registry = MetricsRegistry()
        registry.gauge("tune/round_us").set(
            100.0 + 50.0 / point.chunk_rounds
            + (5.0 if point.transport != "dense" else 0.0))
        registry.gauge("tune/bytes_per_client_round").set(
            168.0 * (point.ratio if point.transport != "dense" else 1.0))
        registry.gauge("tune/staleness_mean").set(0.0)
        return self.score(point, registry.snapshot())


def _problem_engines(kw_a, kw_b, rounds=8, chunk=4):
    """Run the same problem under two engine configs; return final states
    and metrics."""
    data, reg, grad_fn, full_g, params0, L = logreg_problem(
        n_clients=8, m=24, d=12, alpha=5, beta=5, lam=0.01)
    tau = 3
    alg = DProxAlgorithm(reg, DProxConfig(tau=tau, eta=0.02, eta_g=2.0))
    sup = ArraySupplier.from_dataset(data, tau, 4, seed=3)
    out = []
    for kw in (kw_a, kw_b):
        eng = make_engine(alg, grad_fn, data.n_clients, chunk_rounds=chunk,
                          **kw)
        state = eng.init(params0)
        state, metrics = eng.run(state, sup, rounds, seed=0)
        out.append((state, metrics))
    return out


def _assert_states_bitwise(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# records: save/load round-trip
# ---------------------------------------------------------------------------


def _record(host, wsig, ssig, **over):
    key = record_key(host, wsig, ssig)
    rec = {
        "key": key, "host": host, "workload": wsig, "space": ssig,
        "budget": 4, "rounds": 16, "seed": 0,
        "best": {"point": TrialPoint().to_dict(), "objective": 123.4,
                 "round_us": 110.0, "bytes_per_client_round": 168.0,
                 "staleness_mean": 0.0, "rounds": 16},
        "trials": [{"point": TrialPoint().to_dict(), "objective": 123.4,
                    "round_us": 110.0, "bytes_per_client_round": 168.0}],
    }
    rec.update(over)
    return rec


def test_record_roundtrip_hostile_keys(tmp_path):
    # hostile signature content: path separators, dots, spaces, unicode --
    # none of it may reach the filesystem name, all of it must round-trip
    host = {"hostname": "../../etc/passwd", "backend": "cpu",
            "device_kind": "weird/device é", "jax_version": "0.9",
            "x64": True}
    wsig = {"kind": "logreg", "note": "a b/c\\d.json"}
    ssig = {"ratio": [0.1, 0.25]}
    rec = _record(host, wsig, ssig)
    path = save_record(rec, str(tmp_path))
    assert os.path.dirname(path) == str(tmp_path)
    base = os.path.basename(path)
    assert base == f"tune_{rec['key'][:16]}.json"  # hash only, no raw sig
    loaded = load_record(rec["key"], str(tmp_path), host=host,
                         workload_sig=wsig, space_sig=ssig)
    assert loaded is not None
    assert loaded["schema"] == SCHEMA
    assert loaded["host"] == host
    assert loaded["best"]["objective"] == pytest.approx(123.4)
    # provenance was stamped on save with the bench fields
    for field in ("git_commit", "hostname", "jax_version", "backend",
                  "timestamp_utc"):
        assert field in loaded["provenance"]
    assert validate_record(loaded) == []


def test_record_load_rejects_mismatch_and_corruption(tmp_path):
    host = host_signature()
    wsig = Workload().signature()
    ssig = SearchSpace().signature()
    rec = _record(host, wsig, ssig)
    save_record(rec, str(tmp_path))
    key = rec["key"]
    # signature mismatch: a different workload never hits this record
    other = Workload(n_clients=99).signature()
    assert load_record(key, str(tmp_path), workload_sig=other) is None
    # content edit breaks the key <-> signature binding
    path = record_path(key, str(tmp_path))
    edited = json.load(open(path))
    edited["workload"] = {"kind": "tampered"}
    json.dump(edited, open(path, "w"))
    assert load_record(key, str(tmp_path)) is None
    # truncated JSON is a miss, not a crash
    with open(path, "w") as f:
        f.write('{"schema": "repro.tune.record/v1", "key"')
    assert load_record(key, str(tmp_path)) is None


def test_validate_record_reports_problems():
    rec = _record(host_signature(), Workload().signature(),
                  SearchSpace().signature())
    rec["schema"] = SCHEMA
    rec["provenance"] = {"git_commit": None, "hostname": "h",
                         "jax_version": "0.9", "backend": "cpu",
                         "timestamp_utc": "2026-01-01T00:00:00+00:00"}
    assert validate_record(rec) == []
    assert any("schema" in e for e in validate_record({**rec,
                                                       "schema": "v0"}))
    bad = dict(rec)
    del bad["trials"]
    assert any("trials" in e for e in validate_record(bad))
    assert any("key" in e for e in validate_record({**rec,
                                                    "key": "0" * 64}))


# ---------------------------------------------------------------------------
# search: determinism + cache skip
# ---------------------------------------------------------------------------


def test_search_deterministic_in_seed(tmp_path):
    w = Workload()
    runs = []
    for _ in range(2):
        runner = FakeRunner(w)
        tune(w, budget=8, seed=7, runner=runner,
             cache_dir=str(tmp_path / "a"), force=True, save=False)
        runs.append(runner.sequence)
    assert runs[0] == runs[1]  # same seed -> identical trial sequence
    other = FakeRunner(w)
    tune(w, budget=8, seed=8, runner=other, cache_dir=str(tmp_path / "a"),
         force=True, save=False)
    assert other.sequence != runs[0]  # the seed actually steers proposals


def test_cache_hit_executes_zero_trials(tmp_path):
    w = Workload()
    first = FakeRunner(w)
    rec1 = tune(w, budget=6, seed=0, runner=first,
                cache_dir=str(tmp_path))
    assert first.measured_trials == 6
    assert rec1["measured_trials"] == 6 and not rec1["cached"]
    second = FakeRunner(w)
    rec2 = tune(w, budget=6, seed=0, runner=second,
                cache_dir=str(tmp_path))
    assert second.measured_trials == 0  # the persisted record answered
    assert rec2["cached"] and rec2["measured_trials"] == 0
    assert rec2["best"]["point"] == rec1["best"]["point"]
    # force re-measures
    third = FakeRunner(w)
    rec3 = tune(w, budget=6, seed=0, runner=third, cache_dir=str(tmp_path),
                force=True)
    assert third.measured_trials == 6 and not rec3["cached"]


def test_search_canonical_points_only():
    w = Workload()  # synchronous: async axes must stay pinned
    space = SearchSpace()
    runner = FakeRunner(w)
    tune(w, budget=10, seed=3, runner=runner, save=False, force=True)
    for key, _ in runner.sequence:
        p = TrialPoint.from_dict(json.loads(key))
        assert space.canonical(p, w) == p
        assert p.buffer_frac == 1.0 and p.queue_depth == 0
        if p.transport == "dense":
            assert p.ratio == 1.0 and p.schedule == "constant"
        else:
            assert p.ratio in space.ratio


def test_engine_config_kwargs_builds_every_axis():
    w = Workload(clock="straggler")
    p = TrialPoint(chunk_rounds=8, transport="topk", ratio=0.25,
                   granularity="global", plane=True, buffer_frac=0.5,
                   queue_depth=2, staleness="poly", schedule="linear")
    kw = engine_config_kwargs(p, w)
    assert kw["chunk_rounds"] == 8 and kw["plane"]
    assert isinstance(scheduled_transport(kw["transport"]), ScheduledTopK)
    assert kw["buffer_size"] == w.n_clients // 2
    assert kw["queue_depth"] == 2
    assert isinstance(kw["clock"], StragglerClock)


# ---------------------------------------------------------------------------
# constant schedule == fixed ratio, bitwise, across stage combos
# ---------------------------------------------------------------------------

_CONST = RatioSchedule(ratio=0.25, kind="constant")
_ASYNC = dict(clock=StragglerClock(slowdown=3.0), buffer_size=4,
              staleness=Staleness("poly"))


@pytest.mark.parametrize("combo", [
    "inline", "inline_global", "async", "async_queue", "async_plane",
])
def test_constant_schedule_bitwise_fixed_ratio(combo):
    gran = "global" if combo == "inline_global" else "leaf"
    fixed = {"transport": TopK(ratio=0.25, granularity=gran)}
    sched = {"transport": ScheduledTopK(schedule=_CONST, granularity=gran)}
    if combo.startswith("async"):
        fixed.update(_ASYNC)
        sched.update(_ASYNC)
    if combo == "async_queue":
        fixed["queue_depth"] = sched["queue_depth"] = 2
    if combo == "async_plane":
        fixed["plane"] = sched["plane"] = True
    (s_fixed, m_fixed), (s_sched, m_sched) = _problem_engines(fixed, sched)
    _assert_states_bitwise(s_fixed, s_sched)
    np.testing.assert_array_equal(m_fixed["train_loss"],
                                  m_sched["train_loss"])


def test_adaptive_schedule_saves_measured_bytes():
    """The schedule's reason to exist: on a straggler workload the
    linear-in-age ratios uplink fewer measured bytes than constant."""
    const = {"transport": ScheduledTopK(schedule=_CONST), **_ASYNC,
             "queue_depth": 2}
    linear = {"transport": ScheduledTopK(
        schedule=as_schedule("linear", 0.25)), **_ASYNC, "queue_depth": 2}
    (_, m_const), (_, m_lin) = _problem_engines(const, linear, rounds=16)
    b_const = float(np.sum(m_const["uplink_bytes"]))
    b_lin = float(np.sum(m_lin["uplink_bytes"]))
    assert b_const > 0 and b_lin > 0
    assert b_lin < b_const  # stale clients compressed harder
    # ages actually flowed: the workload produced non-zero staleness
    assert float(np.mean(m_lin["staleness_mean"])) > 0


def test_uplink_bytes_metric_only_for_scheduled_transports():
    fixed = {"transport": TopK(ratio=0.25), **_ASYNC}
    sched = {"transport": ScheduledTopK(schedule=_CONST), **_ASYNC}
    (_, m_fixed), (_, m_sched) = _problem_engines(fixed, sched)
    assert "uplink_bytes" not in m_fixed
    assert "uplink_bytes" in m_sched


# ---------------------------------------------------------------------------
# measured runner (one real trial: objective comes from obs instruments)
# ---------------------------------------------------------------------------


def test_trial_runner_scores_from_obs_snapshot():
    runner = TrialRunner(Workload(n_clients=6, m_per_client=20, dim=10),
                         rounds=8, reps=1)
    res = runner.measure(TrialPoint(chunk_rounds=4))
    assert runner.measured_trials == 1
    assert res.round_us > 0
    # dense logreg message: d+1 float64 coordinates
    assert res.bytes_per_client_round == pytest.approx(8 * 11)
    g = res.snapshot["gauges"]
    assert g["tune/round_us"] == pytest.approx(res.round_us)
    assert res.objective == pytest.approx(
        res.round_us + runner.bytes_weight * res.bytes_per_client_round)


def test_deprecated_hillclimb_alias_forwards():
    import importlib
    import warnings

    import repro.launch.hillclimb  # may be cached from a prior import

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        mod = importlib.reload(repro.launch.hillclimb)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    from repro.tune import pairs

    assert mod.run_pair is pairs.run_pair
    assert set(mod.PAIRS) == {"stablelm", "gemma2", "deepseek"}
