"""shard_map expert-parallel MoE vs the dense reference (8 host devices)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.layers import MoECfg, init_moe, moe
from repro.models.moe_ep import moe_expert_parallel

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("model",))
cfg = MoECfg(num_experts=8, top_k=2, d_ff_expert=32,
             capacity_factor=8.0 / 2 + 0.5)  # lossless
d, T = 16, 64
p, _ = init_moe(jax.random.PRNGKey(0), cfg, d, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(T, d)) * 0.5, jnp.float32)

# dense reference (batch-shaped input)
ref, aux_ref = moe(p, cfg, x[None], act="swiglu")
ref = ref[0]

xs = jax.device_put(x, NamedSharding(mesh, P("model", None)))
ps = {
    "router": jax.device_put(p["router"], NamedSharding(mesh, P(None, None))),
    "w_gate": jax.device_put(p["w_gate"], NamedSharding(mesh, P("model", None, None))),
    "w_up": jax.device_put(p["w_up"], NamedSharding(mesh, P("model", None, None))),
    "w_down": jax.device_put(p["w_down"], NamedSharding(mesh, P("model", None, None))),
}
out, aux = jax.jit(
    lambda ps, xs: moe_expert_parallel(ps, cfg, xs, mesh, act="swiglu"))(ps, xs)
err = float(jnp.max(jnp.abs(out - ref)))
aux_err = abs(float(aux) - float(aux_ref))
print("max_err", err, "aux_err", aux_err)
assert err < 1e-4, err
assert aux_err < 1e-4, (float(aux), float(aux_ref))

# HLO contains explicit all-to-alls, no all-gathers of activations
txt = jax.jit(lambda ps, xs: moe_expert_parallel(ps, cfg, xs, mesh)) \
    .lower(ps, xs).compile().as_text()
assert "all-to-all" in txt
print("MOE_EP_OK")
"""


@pytest.mark.slow
def test_expert_parallel_matches_dense_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "MOE_EP_OK" in out.stdout
