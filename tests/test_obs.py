"""Observability tests: the disabled-tracer bitwise pin, the Chrome
trace-event schema, the cross-process merge, and metrics properties.

The load-bearing pin: instrumentation sites live in hot paths permanently,
so the DISABLED path (NULL_TRACER, the default) must be a true no-op --
a run with a tracer installed must produce bitwise-identical numerics to
one without.  The merge tests pin what the CI smoke job's validator
checks on a real 2-process trace: schema-valid events and proper span
nesting per (pid, tid) track after clock-offset alignment.
"""
import json
import os
import sys

import numpy as np
import pytest
from _hypo import given, st  # hypothesis, or fixed-grid fallback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

from repro.fed.runtime import RuntimeArgs, _fields_bitwise, run_local
from repro.obs import metrics as obs_metrics
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace


def _args(**kw) -> RuntimeArgs:
    defaults = dict(clients=4, m=8, dim=12, tau=2, rounds=4, chunk=2,
                    timeout=60.0)
    defaults.update(kw)
    return RuntimeArgs(**defaults)


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test starts and ends with tracing disabled."""
    obs_trace.uninstall()
    yield
    obs_trace.uninstall()


class TestTracerBitwise:
    def test_traced_run_is_bitwise_identical(self):
        """THE pin: installing a tracer must not perturb numerics -- the
        span sites only read the clock, never touch values."""
        base = run_local(_args())
        tracer = obs_trace.install("test")
        try:
            traced = run_local(_args())
        finally:
            obs_trace.uninstall()
        assert tracer.n_spans > 0  # the engine sites actually recorded
        assert _fields_bitwise(base["fields"], traced["fields"])

    def test_null_span_is_shared_noop(self):
        # disabled-path cost model: no allocation per call site
        assert obs_trace.span("a") is obs_trace.span("b")
        obs_trace.span("a").set(nbytes=1)  # no-op, no error

    def test_timed_measures_without_tracer(self):
        with obs_trace.timed("x", "t") as tm:
            pass
        assert tm.seconds >= 0.0
        assert isinstance(obs_trace.get(), obs_trace.NullTracer)


class TestChromeExport:
    def test_export_schema_valid(self):
        tr = obs_trace.Tracer("p0", capacity=64)
        with tr.span("outer", "cat", k=1):
            with tr.span("inner", "cat") as sp:
                sp.set(nbytes=7)
        doc = obs_trace.to_chrome([tr.export_wire()])
        assert obs_trace.validate_chrome(doc) == []
        doc2 = json.loads(json.dumps(doc))  # JSON round trip stays valid
        assert obs_trace.validate_chrome(doc2) == []
        evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert {e["name"] for e in evs} == {"outer", "inner"}
        assert min(e["ts"] for e in evs) == 0.0  # rebased to zero
        inner = next(e for e in evs if e["name"] == "inner")
        assert inner["args"] == {"nbytes": 7}

    def test_ring_wrap_drops_oldest(self):
        tr = obs_trace.Tracer("p0", capacity=4)
        for i in range(10):
            tr.instant(f"s{i}")
        assert tr.n_spans == 4
        assert tr.dropped == 6
        b = tr.export_wire()
        names = [b["names"][ix] for ix in b["name_ix"]]
        assert names == ["s6", "s7", "s8", "s9"]  # oldest-first survivors
        assert list(np.argsort(b["t0"])) == [0, 1, 2, 3]

    def test_merge_applies_offset_and_nests(self):
        srv = obs_trace.Tracer("server", capacity=16)
        wrk = obs_trace.Tracer("worker0", capacity=16)
        wrk.pid = srv.pid + 1  # two tracers in one test process
        wrk.offset = 123.456
        with srv.span("server/commit", "server"):
            pass
        with wrk.span("exec/chunk", "exec", start_round=0):
            with wrk.span("exec/host_sync", "exec"):
                pass
        doc = obs_trace.to_chrome([srv.export_wire(), wrk.export_wire()])
        assert obs_trace.validate_chrome(doc) == []
        evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert len({e["pid"] for e in evs}) == 2
        # the worker ran at the same real time but its offset pushes it
        # ~123.456s later on the merged (server) timebase
        chunk = next(e for e in evs if e["name"] == "exec/chunk")
        commit = next(e for e in evs if e["name"] == "server/commit")
        assert chunk["ts"] - commit["ts"] > 123e6
        procs = [e["args"]["name"] for e in doc["traceEvents"]
                 if e["ph"] == "M" and e["name"] == "process_name"]
        assert sorted(procs) == ["server", "worker0"]

    def test_merge_dedupes_shared_process(self):
        # the in-process threaded runtime ships ONE shared tracer from
        # both ends; same-pid bundles must not double-count
        tr = obs_trace.Tracer("shared", capacity=8)
        tr.instant("a")
        b = tr.export_wire()
        assert len(obs_trace.merge_wire([b, b, None])) == 1

    def test_validator_rejects_partial_overlap(self):
        doc = {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 0},
            {"name": "b", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 0},
        ]}
        assert obs_trace.validate_chrome(doc)

    def test_validator_accepts_disjoint_and_nested(self):
        doc = {"traceEvents": [
            {"name": "a", "ph": "X", "ts": 0, "dur": 10, "pid": 1, "tid": 0},
            {"name": "b", "ph": "X", "ts": 2, "dur": 4, "pid": 1, "tid": 0},
            {"name": "c", "ph": "X", "ts": 20, "dur": 5, "pid": 1, "tid": 0},
            # same window, other track: never compared
            {"name": "d", "ph": "X", "ts": 5, "dur": 10, "pid": 1, "tid": 1},
        ]}
        assert obs_trace.validate_chrome(doc) == []

    @given(off=st.floats(-100.0, 100.0), lat=st.floats(0.0, 0.5))
    def test_clock_offset_recovers_true_offset(self, off, lat):
        """Symmetric-latency exchange: the midpoint estimate is exact."""
        t_send = 10.0
        peer_now = (t_send + lat) + off  # peer clock = local + off
        t_recv = t_send + 2.0 * lat
        est = obs_trace.clock_offset(t_send, t_recv, peer_now)
        assert est == pytest.approx(off, abs=1e-9)


class TestOverlapReport:
    def _doc(self, events):
        return {"traceEvents": events}

    def test_hidden_fraction_from_synthetic_spans(self):
        s = 1e6  # seconds -> µs
        doc = self._doc([
            {"name": "exec/chunk", "ph": "X", "ts": 0, "dur": 1 * s,
             "pid": 1, "tid": 0, "args": {"start_round": 0, "rounds": 2}},
            {"name": "exec/chunk", "ph": "X", "ts": 1 * s, "dur": 1 * s,
             "pid": 1, "tid": 0, "args": {"start_round": 2, "rounds": 2}},
            # chunk 0's ship rides entirely behind chunk 1's compute
            {"name": "uplink/ship", "ph": "X", "ts": 1 * s, "dur": 1 * s,
             "pid": 1, "tid": 1, "args": {"start_round": 0, "nbytes": 100}},
            # chunk 1's ship is fully exposed after the last compute
            {"name": "uplink/ship", "ph": "X", "ts": 2 * s, "dur": 1 * s,
             "pid": 1, "tid": 1, "args": {"start_round": 2, "nbytes": 100}},
        ])
        rep = obs_report.overlap_report(doc)
        t = rep["totals"]
        assert t["chunks"] == 2
        assert t["compute_s"] == pytest.approx(2.0)
        assert t["wire_s"] == pytest.approx(2.0)
        assert t["wall_s"] == pytest.approx(3.0)
        assert t["hidden_fraction"] == pytest.approx(0.5)
        # steady drops the pid's first chunk: one chunk, ship exposed
        assert rep["steady"]["chunks"] == 1
        assert rep["steady"]["hidden_fraction"] == pytest.approx(0.0)

    def test_inline_wait_subtracted_once(self):
        """Blocking mode: uplink/wait wraps the inline ship on the SAME
        thread -- union, not sum, or compute goes negative."""
        s = 1e6
        doc = self._doc([
            {"name": "exec/chunk", "ph": "X", "ts": 0, "dur": 2 * s,
             "pid": 1, "tid": 0, "args": {"start_round": 0, "rounds": 2}},
            {"name": "uplink/wait", "ph": "X", "ts": 1 * s, "dur": 1 * s,
             "pid": 1, "tid": 0, "args": {"start_round": 0}},
            {"name": "uplink/ship", "ph": "X", "ts": 1 * s, "dur": 0.9 * s,
             "pid": 1, "tid": 0, "args": {"start_round": 0, "nbytes": 10}},
        ])
        rep = obs_report.overlap_report(doc)
        assert rep["chunks"][0]["compute_s"] == pytest.approx(1.0)

    def test_compute_ref_charges_dilation_to_wire(self):
        s = 1e6
        doc = self._doc([
            {"name": "exec/chunk", "ph": "X", "ts": 0, "dur": 1 * s,
             "pid": 1, "tid": 0, "args": {"start_round": 0, "rounds": 2}},
            # steady chunk dilated to 1.2s by sender contention
            {"name": "exec/chunk", "ph": "X", "ts": 1 * s, "dur": 1.2 * s,
             "pid": 1, "tid": 0, "args": {"start_round": 2, "rounds": 2}},
            {"name": "uplink/ship", "ph": "X", "ts": 1 * s, "dur": 1.2 * s,
             "pid": 1, "tid": 1, "args": {"start_round": 2, "nbytes": 10}},
        ])
        rep = obs_report.overlap_report(doc, compute_ref_s=1.0)
        st_ = rep["steady"]
        # trace-only view: wire fully hidden (wall == dilated compute)
        assert st_["hidden_fraction"] == pytest.approx(1.0)
        # reference view: the 0.2s dilation is exposed wire time
        assert st_["hidden_fraction_ref"] == pytest.approx(1.0 - 0.2 / 1.2)


class TestMetrics:
    @given(v=st.floats(0.0, 1e6), n=st.integers(1, 5))
    def test_counter_accumulates(self, v, n):
        c = obs_metrics.Counter("c")
        for _ in range(n):
            c.add(v)
        assert c.value == pytest.approx(n * v)

    def test_counter_rejects_negative(self):
        with pytest.raises(ValueError):
            obs_metrics.Counter("c").add(-1.0)

    @given(v=st.floats(-5.0, 50.0))
    def test_integer_buckets_clip(self, v):
        """The AGE_HIST_BUCKETS idiom: bucket = clip(int(v), 0, n-1)."""
        h = obs_metrics.Histogram("h", buckets=8)
        h.observe(v)
        expect = min(max(int(v), 0), 7)
        assert h.counts[expect] == 1
        assert int(h.counts.sum()) == 1 == h.n
        assert h.mean == pytest.approx(v)

    @given(n=st.integers(1, 64))
    def test_observe_array_counts_every_value(self, n):
        h = obs_metrics.Histogram("h", buckets=4)
        h.observe(np.arange(n) % 9 - 1.0)
        assert int(h.counts.sum()) == n == h.n

    def test_edges_histogram(self):
        h = obs_metrics.Histogram("h", edges=[1.0, 2.0, 4.0])
        h.observe([0.5, 1.5, 3.0, 100.0])
        assert h.counts.tolist() == [1, 1, 1, 1]

    def test_exactly_one_geometry(self):
        with pytest.raises(ValueError):
            obs_metrics.Histogram("h")
        with pytest.raises(ValueError):
            obs_metrics.Histogram("h", buckets=4, edges=[1.0])

    def test_merge_counts_folds_ledger_histogram(self):
        """sched's arrival-age buckets fold in unchanged -- the geometries
        are pinned equal."""
        from repro.sched.aggregator import AGE_HIST_BUCKETS

        assert obs_metrics.AGE_BUCKETS == AGE_HIST_BUCKETS
        h = obs_metrics.Histogram("age", buckets=AGE_HIST_BUCKETS)
        ext = np.zeros(AGE_HIST_BUCKETS, np.int64)
        ext[2] = 3
        h.merge_counts(ext)
        assert h.n == 3 and h.counts[2] == 3 and h.sum == pytest.approx(6.0)
        with pytest.raises(ValueError):
            h.merge_counts(np.zeros(3, np.int64))

    def test_registry_type_mismatch(self):
        r = obs_metrics.MetricsRegistry()
        r.counter("x")
        with pytest.raises(TypeError):
            r.gauge("x")

    def test_registry_get_or_create(self):
        r = obs_metrics.MetricsRegistry()
        assert r.counter("x") is r.counter("x")
        assert r.histogram("h").buckets == obs_metrics.AGE_BUCKETS

    def test_jsonl_sink_roundtrip(self, tmp_path):
        path = str(tmp_path / "m.jsonl")
        r = obs_metrics.MetricsRegistry()
        r.counter("uplink/bytes").add(42.0)
        r.gauge("round_throughput").set(3.5)
        r.histogram("arrival/age").observe([0, 1, 1, 99])
        with obs_metrics.JsonlSink(path) as sink:
            sink.write("commit", worker=0, nbytes=42)
            sink.write_snapshot(r, rounds=8)
        recs = [json.loads(line) for line in open(path)]
        assert [rec["event"] for rec in recs] == ["commit", "snapshot"]
        assert all(rec["schema"] == obs_metrics.SCHEMA for rec in recs)
        snap = recs[1]["metrics"]
        assert snap["counters"]["uplink/bytes"] == 42.0
        assert snap["gauges"]["round_throughput"] == 3.5
        h = snap["histograms"]["arrival/age"]
        assert h["n"] == 4 and h["counts"][1] == 2 and h["counts"][7] == 1


class TestRuntimeTraceEndToEnd:
    def test_threaded_pair_writes_merged_trace(self, tmp_path):
        """The in-process pair (same sockets/frames as the subprocess
        form) exports one schema-valid merged trace + metrics JSONL."""
        import threading

        from repro.fed.runtime import run_server, run_worker

        trace_path = str(tmp_path / "t.json")
        jsonl_path = str(tmp_path / "m.jsonl")
        a = _args(mode="overlapped", trace=trace_path,
                  metrics_jsonl=jsonl_path)
        box = {}
        ready = threading.Event()
        t = threading.Thread(
            target=lambda: box.update(server=run_server(
                a, ready_cb=lambda p: (box.update(port=p), ready.set()))),
            daemon=True)
        t.start()
        assert ready.wait(30)
        a.port = box["port"]
        run_worker(a, rank=0)
        t.join(60)
        assert box["server"]["trace_path"] == trace_path
        doc = json.load(open(trace_path))
        assert obs_trace.validate_chrome(doc) == []
        names = {e["name"] for e in doc["traceEvents"]
                 if e.get("ph") == "X"}
        # the one-timebase pin: engine, wire, and server spans coexist
        assert {"exec/chunk", "uplink/ship", "server/commit"} <= names
        snap = box["server"]["metrics"]
        assert snap["counters"]["uplink/bytes"] > 0
        assert snap["counters"]["commits"] == 2  # 4 rounds / chunk 2
        lines = [json.loads(line) for line in open(jsonl_path)]
        assert [rec["event"] for rec in lines].count("commit") == 2
        assert lines[-1]["event"] == "snapshot"
