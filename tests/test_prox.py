"""Property-based tests for the regularizers (repro.core.prox).

Invariants checked with hypothesis:
  * prox is firmly non-expansive: ||P(x)-P(y)|| <= ||x-y||;
  * prox optimality: eta*g(P(x)) + 1/2||x-P(x)||^2 <= eta*g(u) + 1/2||x-u||^2;
  * soft-threshold closed form matches the definition;
  * prox(x, 0) = x; masks leave masked leaves untouched.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or fixed-grid fallback

from repro.core.prox import L1, ElasticNet, GroupL2, LinfBall, Zero, soft_threshold

REGS = [
    L1(lam=0.1),
    ElasticNet(lam1=0.05, lam2=0.2),
    GroupL2(lam=0.1),
    LinfBall(radius=0.7),
    Zero(),
]

arrays = st.integers(0, 2**31 - 1).map(
    lambda seed: np.random.default_rng(seed).normal(size=(4, 6)).astype(np.float64)
)


@pytest.mark.parametrize("reg", REGS, ids=lambda r: type(r).__name__)
@given(seed=st.integers(0, 2**31 - 1), eta=st.floats(0.01, 10.0))
@settings(max_examples=25, deadline=None)
def test_prox_nonexpansive(reg, seed, eta):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 6)))
    y = jnp.asarray(rng.normal(size=(4, 6)))
    px, py = reg.prox(x, eta), reg.prox(y, eta)
    assert float(jnp.linalg.norm(px - py)) <= float(jnp.linalg.norm(x - y)) + 1e-9


@pytest.mark.parametrize("reg", REGS, ids=lambda r: type(r).__name__)
@given(seed=st.integers(0, 2**31 - 1), eta=st.floats(0.01, 5.0))
@settings(max_examples=25, deadline=None)
def test_prox_optimality(reg, seed, eta):
    """P(x) minimizes eta*g(u) + 1/2||x-u||^2; check against random candidates."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(3, 5)))
    p = reg.prox(x, eta)

    def obj(u):
        return float(eta * reg.value(u) + 0.5 * jnp.sum((x - u) ** 2))

    base = obj(p)
    for _ in range(5):
        u = jnp.asarray(rng.normal(size=(3, 5)))
        assert base <= obj(u) + 1e-8
    # also perturbations around p
    for _ in range(5):
        u = p + 0.01 * jnp.asarray(rng.normal(size=(3, 5)))
        assert base <= obj(u) + 1e-10


@given(seed=st.integers(0, 2**31 - 1), t=st.floats(0.0, 3.0))
@settings(max_examples=50, deadline=None)
def test_soft_threshold_closed_form(seed, t):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=17)
    out = np.asarray(soft_threshold(jnp.asarray(x), t))
    expected = np.sign(x) * np.maximum(np.abs(x) - t, 0.0)
    np.testing.assert_allclose(out, expected, atol=1e-12)


def test_prox_identity_at_zero_eta():
    x = {"a": jnp.arange(5.0), "b": jnp.ones((2, 2))}
    for reg in [L1(lam=0.5), GroupL2(lam=0.5)]:
        p = reg.prox(x, 0.0)
        for k in x:
            np.testing.assert_allclose(np.asarray(p[k]), np.asarray(x[k]))


def test_mask_restricts_prox():
    x = {"w": jnp.ones(4) * 0.05, "b": jnp.ones(2) * 0.05}
    reg = L1(lam=1.0).with_mask({"w": True, "b": False})
    p = reg.prox(x, 1.0)
    np.testing.assert_allclose(np.asarray(p["w"]), 0.0)  # thresholded away
    np.testing.assert_allclose(np.asarray(p["b"]), 0.05)  # untouched
    # value also only counts masked leaves
    assert abs(float(reg.value(x)) - 0.05 * 4) < 1e-6


def test_group_l2_kills_small_groups():
    x = jnp.array([[0.01, 0.01, 0.01], [3.0, 4.0, 0.0]])
    reg = GroupL2(lam=1.0)
    p = np.asarray(reg.prox(x, 0.5))
    np.testing.assert_allclose(p[0], 0.0)  # group norm < eta*lam -> zeroed
    # surviving group shrunk along its direction
    nrm = np.linalg.norm(x[1])
    np.testing.assert_allclose(p[1], np.asarray(x[1]) * (1 - 0.5 / nrm), rtol=1e-6)


def test_linf_ball_clips():
    x = jnp.array([-2.0, 0.3, 5.0])
    p = np.asarray(LinfBall(radius=0.7).prox(x, 123.0))
    np.testing.assert_allclose(p, [-0.7, 0.3, 0.7])
