"""Integration tests tying the implementation to the paper's Section 4.

These are smaller-scale versions of the benchmarks (benchmarks/fig*.py);
EXPERIMENTS.md records the full-scale results.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithm import DProxConfig
from repro.core.baselines import FedDA
from repro.core.prox import L1
from repro.fed.simulator import DProxAlgorithm, run


def test_cnn_parameter_count_matches_paper():
    """Section 4.2: 'The total number of parameters is d = 112,394.'"""
    from repro.models import cnn

    p = cnn.init_params(jax.random.PRNGKey(0))
    d = sum(int(x.size) for x in jax.tree_util.tree_leaves(p))
    assert d == 112_394, d


def test_mnist_like_split_is_heterogeneous():
    from repro.data.mnist_like import generate, heterogeneous_split

    tx, ty, sx, sy = generate(n_train=2000, n_test=200, seed=0)
    data = heterogeneous_split(tx, ty, sx, sy, n_clients=10)
    assert data.n_clients == 10
    # each client dominated by its own label but seeing others
    for i in range(10):
        counts = np.bincount(data.client_y[i], minlength=10)
        assert counts.argmax() == i
        assert (counts > 0).sum() >= 8, "clients should see most classes"
    # sample counts differ across clients (paper: 'may differ')
    sizes = [len(y) for y in data.client_y]
    assert len(set(sizes)) > 1 or sizes[0] * 10 == sum(sizes)


@pytest.mark.slow
def test_federated_cnn_learns_and_beats_fedda():
    """Fig. 4 (reduced): ours reaches higher accuracy than FedDA in the same
    number of rounds on the heterogeneous split."""
    from repro.data.mnist_like import (generate, heterogeneous_split,
                                       sample_round_batches)
    from repro.models import cnn

    tx, ty, sx, sy = generate(n_train=3000, n_test=800, seed=0)
    data = heterogeneous_split(tx, ty, sx, sy, n_clients=10)
    reg = L1(lam=1e-4)
    grad_fn = cnn.make_grad_fn()
    p0 = cnn.init_params(jax.random.PRNGKey(0))
    tau, R = 5, 40
    supplier = lambda r, rng: sample_round_batches(data, tau, 10, rng)
    test_x, test_y = jnp.asarray(data.test_x), jnp.asarray(data.test_y)
    eval_fn = lambda p: {"acc": cnn.accuracy(p, test_x, test_y)}
    h = run(DProxAlgorithm(reg, DProxConfig(tau=tau, eta=0.005, eta_g=1.5)),
            p0, grad_fn, supplier, 10, R, eval_fn=eval_fn, eval_every=R)
    h_da = run(FedDA(reg, tau, 0.005, 1.5),
               p0, grad_fn, supplier, 10, R, eval_fn=eval_fn, eval_every=R)
    ours, fedda = h.extra["acc"][-1], h_da.extra["acc"][-1]
    assert ours > 0.7, f"CNN failed to learn: acc={ours}"
    assert ours >= fedda - 0.02, (ours, fedda)


def test_synthetic_logreg_satisfies_prox_pl_convergence():
    """The sparse-logreg problem is prox-PL (paper cites Karimi et al.):
    Theorem 3.6 then gives LINEAR convergence of Omega^r.  Check the
    loss-value sequence decays geometrically-ish with full gradients."""
    from benchmarks.common import logreg_problem
    from repro.data.synthetic import make_round_batches

    data, reg, grad_fn, full_g, params0, L = logreg_problem(
        n_clients=8, m=60, d=12, x64=True)
    tau, eta_g = 5, 3.0
    eta_tilde = 0.5 / L
    cfg = DProxConfig(tau=tau, eta=eta_tilde / (eta_g * tau), eta_g=eta_g)
    supplier = lambda r, rng: make_round_batches(data, tau, None, rng)
    h = run(DProxAlgorithm(reg, cfg), params0, grad_fn, supplier, 8, 1500,
            reg=reg, eta_tilde=eta_tilde, full_grad_fn=full_g, eval_every=300)
    opt = h.optimality
    # monotone-ish decrease over eval points and large total reduction
    assert opt[-1] < 1e-3 * opt[1] or opt[-1] < 1e-8
