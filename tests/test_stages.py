"""Composable engine stages (repro.exec.stages) -- the stage-stack refactor.

Pins the contracts the backend-enum retirement is built around:

  * every single-stage configuration is BITWISE its legacy ``backend=``
    counterpart (Placement == sharded, UplinkComm == compressed,
    DownlinkComm == compressed+downlink, Asynchrony == async);
  * the ``backend=`` alias emits a DeprecationWarning and maps onto the
    right stage combination; stage-field configs emit no warning;
  * compositions the enum made impossible now run and degrade to the bare
    engine at their identity points (async + ratio-1.0 transport under a
    zero-delay clock == inline; downlink under async at ratio 1.0 == dense
    async; all three stages at once on the CPU mesh);
  * the multi-slot report queue: depth 1 reproduces the one-slot
    ``AsyncState`` trajectory, deeper queues let clients race ahead of
    delivery (upload-FIFO), and queued runs still train;
  * prefetch donation: suppliers declare staged chunks donatable, the
    engine trajectory is unchanged.
"""
import warnings

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import Dense, DownlinkCompressor, RandK, TopK
from repro.core import algorithm as A
from repro.core.prox import L1
from repro.data.synthetic import logistic_heterogeneous
from repro.exec import ArraySupplier, EngineConfig, RoundEngine
from repro.fed.simulator import DProxAlgorithm
from repro.models import logreg
from repro.sched import (DeterministicClock, QueueState, Staleness,
                         StragglerClock, init_queue_state)
from repro.utils import tree as tu


def _problem(n=6, m=30, d=10, seed=0, lam=0.01):
    data = logistic_heterogeneous(
        n_clients=n, m_per_client=m, d=d, alpha=5, beta=5, seed=seed)
    s = np.linalg.norm(data.features.reshape(-1, d), axis=1).max()
    data.features = (data.features / s).astype(np.float64)
    data.labels = data.labels.astype(np.float64)
    reg = L1(lam=lam)
    grad_fn = logreg.make_grad_fn()
    params0 = {"w": jnp.zeros(d, jnp.float64), "b": jnp.zeros((), jnp.float64)}
    return data, reg, grad_fn, params0


def _dprox(reg, tau=3, eta=0.05, eta_g=2.0):
    return DProxAlgorithm(reg, A.DProxConfig(tau=tau, eta=eta, eta_g=eta_g))


def _legacy(**kw):
    """An EngineConfig built through the deprecated backend= alias (the
    DeprecationWarning fires lazily at resolve time -- _run suppresses it
    around engine construction)."""
    return EngineConfig(**kw)


def _run(alg, grad_fn, n_clients, cfg, params0, sup, rounds):
    with warnings.catch_warnings():
        if cfg.backend is not None:  # the deprecated alias under test
            warnings.simplefilter("ignore", DeprecationWarning)
        eng = RoundEngine(alg, grad_fn, n_clients, cfg)
    state = eng.init(params0)
    state, metrics = eng.run(state, sup, rounds, seed=0)
    return eng, state, metrics


def _assert_states_equal(a, b, exact=True):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-12, atol=1e-14)


# ---------------------------------------------------------------------------
# (a) single-stage == legacy backend, bitwise
# ---------------------------------------------------------------------------


def test_placement_stage_matches_legacy_sharded_bitwise():
    from repro.launch.mesh import make_mesh_compat

    data, reg, grad_fn, params0 = _problem(seed=1)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=2)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    pspecs = {"w": ("mlp",), "b": ()}
    alg = _dprox(reg)
    _, s_new, m_new = _run(
        alg, grad_fn, data.n_clients,
        EngineConfig(chunk_rounds=3, mesh=mesh, param_specs=pspecs),
        params0, sup, 6)
    _, s_old, m_old = _run(
        alg, grad_fn, data.n_clients,
        _legacy(backend="sharded", chunk_rounds=3, mesh=mesh,
                param_specs=pspecs), params0, sup, 6)
    _assert_states_equal(s_new, s_old)
    np.testing.assert_array_equal(m_new["train_loss"], m_old["train_loss"])


def test_uplink_stage_matches_legacy_compressed_bitwise():
    data, reg, grad_fn, params0 = _problem(seed=2)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=3)
    alg = _dprox(reg)
    tr = TopK(ratio=0.5)
    _, s_new, m_new = _run(alg, grad_fn, data.n_clients,
                           EngineConfig(chunk_rounds=3, transport=tr),
                           params0, sup, 6)
    _, s_old, m_old = _run(alg, grad_fn, data.n_clients,
                           _legacy(backend="compressed", chunk_rounds=3,
                                   transport=tr), params0, sup, 6)
    _assert_states_equal(s_new, s_old)
    np.testing.assert_array_equal(m_new["train_loss"], m_old["train_loss"])


def test_downlink_stage_matches_legacy_compressed_downlink_bitwise():
    data, reg, grad_fn, params0 = _problem(seed=3)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=4)
    alg = _dprox(reg)
    _, s_new, _ = _run(alg, grad_fn, data.n_clients,
                       EngineConfig(chunk_rounds=2, downlink=TopK(ratio=0.5)),
                       params0, sup, 6)
    _, s_old, _ = _run(alg, grad_fn, data.n_clients,
                       _legacy(backend="compressed", chunk_rounds=2,
                               downlink=TopK(ratio=0.5)), params0, sup, 6)
    _assert_states_equal(s_new, s_old)


def test_asynchrony_stage_matches_legacy_async_bitwise():
    data, reg, grad_fn, params0 = _problem(seed=4)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=5)
    alg = _dprox(reg)
    kw = dict(chunk_rounds=2, clock=StragglerClock(slowdown=4.0, jitter=0.0),
              buffer_size=3, staleness=Staleness("poly", correct=True))
    _, s_new, m_new = _run(alg, grad_fn, data.n_clients, EngineConfig(**kw),
                           params0, sup, 8)
    _, s_old, m_old = _run(alg, grad_fn, data.n_clients,
                           _legacy(backend="async", **kw), params0, sup, 8)
    _assert_states_equal(s_new, s_old)
    np.testing.assert_array_equal(m_new["vtime"], m_old["vtime"])
    np.testing.assert_array_equal(m_new["staleness_mean"],
                                  m_old["staleness_mean"])


# ---------------------------------------------------------------------------
# (d) the backend= alias: DeprecationWarning + correct mapping
# ---------------------------------------------------------------------------


def test_backend_alias_emits_deprecation_and_maps():
    with pytest.warns(DeprecationWarning, match="deprecated"):
        stack = EngineConfig(backend="compressed").resolve()
    assert stack.uplink is not None and stack.asynchrony is None
    with pytest.warns(DeprecationWarning):
        stack = EngineConfig(backend="async").resolve()
    assert stack.asynchrony is not None and stack.uplink is not None
    with pytest.warns(DeprecationWarning):
        stack = EngineConfig(backend="inline").resolve()
    assert stack.names() == ()
    with pytest.warns(DeprecationWarning):
        stack = EngineConfig(backend="protocol").resolve()
    assert stack.protocol and not stack.split
    from repro.launch.mesh import make_mesh_compat

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    with pytest.warns(DeprecationWarning):
        stack = EngineConfig(backend="sharded", mesh=mesh,
                             param_specs={"w": ("mlp",)}).resolve()
    assert stack.placement is not None
    # unknown names still fail loudly, before any mapping
    with pytest.raises(ValueError, match="backend"):
        EngineConfig(backend="warp").validate()


def test_stage_fields_emit_no_deprecation_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        EngineConfig(transport=TopK(ratio=0.5)).validate()
        EngineConfig(clock="straggler", buffer_size=2,
                     downlink=Dense()).validate()
        EngineConfig(protocol=True).validate()


def test_stage_names_reflect_composition():
    stack = EngineConfig(transport=Dense(), clock="straggler",
                         downlink=Dense()).resolve()
    assert stack.names() == ("uplink", "downlink", "asynchrony")
    assert EngineConfig().resolve().names() == ()
    assert EngineConfig(protocol=True).resolve().names() == ("protocol",)


# ---------------------------------------------------------------------------
# (b) compositions degrade to the bare engine at their identity points
# ---------------------------------------------------------------------------


def test_async_plus_ratio_one_uplink_zero_delay_is_inline_bitwise():
    """The composition the enum forbade: Asynchrony + UplinkComm at their
    identity points IS the synchronous uncompressed engine."""
    data, reg, grad_fn, params0 = _problem(seed=5)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=6)
    alg = _dprox(reg)
    _, s_in, m_in = _run(alg, grad_fn, data.n_clients,
                         EngineConfig(chunk_rounds=3), params0, sup, 7)
    _, s_c, m_c = _run(alg, grad_fn, data.n_clients,
                       EngineConfig(chunk_rounds=3, transport=TopK(ratio=1.0),
                                    clock=DeterministicClock()),
                       params0, sup, 7)
    _assert_states_equal(s_in, s_c)
    np.testing.assert_array_equal(m_in["train_loss"], m_c["train_loss"])


def test_async_downlink_ratio_one_matches_dense_async():
    """DownlinkComm threads its shadow through the async carry; at ratio
    1.0 the shadow is bitwise the server state, so the composition matches
    the downlink-free async run (ROADMAP: downlink compression under
    async)."""
    data, reg, grad_fn, params0 = _problem(seed=6)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=7)
    alg = _dprox(reg)
    clock = DeterministicClock(per_client=(1.0, 2.0, 3.0, 1.0, 2.0, 3.0))
    base = dict(chunk_rounds=2, clock=clock, buffer_size=4,
                staleness=Staleness("poly", correct=True))
    _, s_d, m_d = _run(alg, grad_fn, data.n_clients, EngineConfig(**base),
                       params0, sup, 8)
    for dl in (Dense(), TopK(ratio=1.0), DownlinkCompressor(Dense())):
        _, s_c, m_c = _run(alg, grad_fn, data.n_clients,
                           EngineConfig(downlink=dl, **base), params0, sup, 8)
        _assert_states_equal(s_d, s_c)
        np.testing.assert_array_equal(m_d["train_loss"], m_c["train_loss"])
        np.testing.assert_array_equal(m_d["vtime"], m_c["vtime"])


def test_async_downlink_compressed_trains_and_reports_bytes():
    data, reg, grad_fn, params0 = _problem(seed=7)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=8)
    alg = _dprox(reg)
    eng, state, m = _run(
        alg, grad_fn, data.n_clients,
        EngineConfig(chunk_rounds=4, transport=TopK(ratio=0.5),
                     downlink=TopK(ratio=0.5),
                     clock=StragglerClock(slowdown=4.0), buffer_size=3,
                     staleness=Staleness("poly", correct=True)),
        params0, sup, 24)
    losses = m["train_loss"]
    assert len(losses) == 24 and np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert bool(tu.tree_isfinite(state.x_bar))
    assert max(m["staleness_max"]) > 0  # stale reports DID flow
    # both wire directions accounted: x_bar (11 f64) at top-k half
    assert eng.uplink_bytes_per_client_round == 6 * (8 + 4)
    assert eng.downlink_bytes_per_client_round == 6 * (8 + 4)


def test_async_downlink_invariant_to_chunking():
    data, reg, grad_fn, params0 = _problem(seed=8)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=9)
    alg = _dprox(reg)
    states = []
    for ch in (1, 4):
        _, s, _ = _run(alg, grad_fn, data.n_clients,
                       EngineConfig(chunk_rounds=ch, downlink=TopK(ratio=0.5),
                                    clock=StragglerClock(slowdown=4.0),
                                    buffer_size=3, transport=RandK(ratio=0.5)),
                       params0, sup, 6)
        states.append(s)
    _assert_states_equal(states[0], states[1])


# ---------------------------------------------------------------------------
# all three stages at once (the acceptance composition)
# ---------------------------------------------------------------------------


def test_full_stack_identity_points_match_inline():
    """Placement + UplinkComm + Asynchrony all active at their identity
    points reproduces the bare inline trajectory on the CPU mesh."""
    from repro.launch.mesh import make_mesh_compat

    data, reg, grad_fn, params0 = _problem(seed=9)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=10)
    alg = _dprox(reg)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    _, s_in, m_in = _run(alg, grad_fn, data.n_clients,
                         EngineConfig(chunk_rounds=2), params0, sup, 6)
    _, s_f, m_f = _run(
        alg, grad_fn, data.n_clients,
        EngineConfig(chunk_rounds=2, mesh=mesh,
                     param_specs={"w": ("mlp",), "b": ()},
                     transport=TopK(ratio=1.0), clock=DeterministicClock()),
        params0, sup, 6)
    _assert_states_equal(s_in, s_f, exact=False)
    np.testing.assert_allclose(m_in["train_loss"], m_f["train_loss"],
                               rtol=1e-6)


def test_full_stack_compressed_async_sharded_end_to_end():
    """mesh + transport + downlink + clock + queue, all non-trivial, in one
    compiled scan -- the composition the backend enum made impossible."""
    from repro.launch.mesh import make_mesh_compat

    data, reg, grad_fn, params0 = _problem(seed=10)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=11)
    alg = _dprox(reg)
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    eng, state, m = _run(
        alg, grad_fn, data.n_clients,
        EngineConfig(chunk_rounds=3, mesh=mesh,
                     param_specs={"w": ("mlp",), "b": ()},
                     transport=TopK(ratio=0.5), downlink=TopK(ratio=0.5),
                     clock=StragglerClock(slowdown=4.0), buffer_size=3,
                     staleness=Staleness("poly", correct=True),
                     queue_depth=2),
        params0, sup, 18)
    assert eng.stack.names() == ("placement", "uplink", "downlink",
                                 "asynchrony")
    losses = m["train_loss"]
    assert len(losses) == 18 and np.isfinite(losses).all()
    assert bool(tu.tree_isfinite(state.x_bar))
    assert (np.diff(m["vtime"]) >= 0).all()


def test_full_stack_multi_device_subprocess():
    """The 4-device host-platform mesh runs the full stack and matches the
    unplaced composition (placement never changes the math)."""
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 4
from repro.comm import TopK
from repro.core.algorithm import DProxConfig
from repro.core.prox import L1
from repro.data.synthetic import logistic_heterogeneous
from repro.exec import ArraySupplier, EngineConfig, RoundEngine
from repro.fed.simulator import DProxAlgorithm
from repro.launch.mesh import make_mesh_compat
from repro.models import logreg
from repro.sched import Staleness, StragglerClock

data = logistic_heterogeneous(n_clients=8, m_per_client=30, d=10,
                              alpha=5, beta=5, seed=0)
data.features = data.features.astype(np.float64)
data.labels = data.labels.astype(np.float64)
reg = L1(lam=0.01)
grad_fn = logreg.make_grad_fn()
params0 = {"w": jnp.zeros(10, jnp.float64), "b": jnp.zeros((), jnp.float64)}
alg = DProxAlgorithm(reg, DProxConfig(tau=3, eta=0.02, eta_g=2.0))
sup = ArraySupplier.from_dataset(data, 3, 8, seed=1)
kw = dict(chunk_rounds=2, transport=TopK(ratio=0.5),
          clock=StragglerClock(slowdown=4.0, jitter=0.0), buffer_size=4,
          staleness=Staleness("poly", correct=True), queue_depth=2)

bare = RoundEngine(alg, grad_fn, 8, EngineConfig(**kw))
s_b, _ = bare.run(bare.init(params0), sup, 6, seed=0)

mesh = make_mesh_compat((2, 2), ("data", "model"))
placed = RoundEngine(alg, grad_fn, 8, EngineConfig(
    mesh=mesh, param_specs={"w": ("mlp",), "b": ()}, plan="A", **kw))
s_p, _ = placed.run(placed.init(params0), sup, 6, seed=0)

diff = float(np.abs(np.asarray(s_b.x_bar["w"]) -
                    np.asarray(s_p.x_bar["w"])).max())
print("maxdiff", diff)
assert diff < 1e-12, diff
# the in-flight queue was placed on the mesh (client axis -> data)
sched = placed._sched_state
assert sched.slot_filled.shape == (2, 8)
print("STAGES_SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "STAGES_SHARDED_OK" in out.stdout


# ---------------------------------------------------------------------------
# (c) the multi-slot report queue
# ---------------------------------------------------------------------------


def test_queue_state_shapes():
    msg = {"v": jax.ShapeDtypeStruct((6, 4), jnp.float32)}
    aux = {"round": jax.ShapeDtypeStruct((6,), jnp.int32)}
    qs = init_queue_state(msg, aux, 6, queue_depth=3, clock_seed=0,
                          with_resid=True)
    assert isinstance(qs, QueueState)
    assert qs.pending_msg["v"].shape == (3, 6, 4)
    assert qs.slot_filled.shape == (3, 6) and not bool(qs.slot_filled.any())
    assert np.isinf(np.asarray(qs.deliver_time)).all()
    assert qs.resid["v"].shape == (6, 4)
    with pytest.raises(ValueError, match="queue_depth"):
        init_queue_state(msg, aux, 6, queue_depth=0, clock_seed=0)
    with pytest.raises(ValueError, match="client axis"):
        init_queue_state({"v": jax.ShapeDtypeStruct((4,), jnp.float32)},
                         aux, 6, queue_depth=2, clock_seed=0)


def test_queue_depth_one_matches_one_slot_buffer():
    """Depth 1 is the queue-form of the one-slot AsyncState semantics: a
    slot frees exactly when the previous report delivered."""
    data, reg, grad_fn, params0 = _problem(seed=11)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=12)
    alg = _dprox(reg)
    base = dict(chunk_rounds=2,
                clock=DeterministicClock(per_client=(1.0, 3.5, 1.5, 2.5,
                                                     0.5, 3.0)),
                buffer_size=3, staleness=Staleness("poly", correct=True))
    eng1, s1, m1 = _run(alg, grad_fn, data.n_clients, EngineConfig(**base),
                        params0, sup, 10)
    engq, sq, mq = _run(alg, grad_fn, data.n_clients,
                        EngineConfig(queue_depth=1, **base), params0, sup, 10)
    _assert_states_equal(s1, sq, exact=False)
    np.testing.assert_array_equal(m1["vtime"], mq["vtime"])
    np.testing.assert_array_equal(m1["staleness_mean"], mq["staleness_mean"])
    np.testing.assert_array_equal(
        np.asarray(eng1._sched_state.last_synced),
        np.asarray(engq._sched_state.last_synced))


def test_queue_depth_lets_clients_race_ahead():
    """With a deeper queue a slow client keeps computing while its uploads
    drain FIFO: more than one report in flight at once (the one-slot buffer
    caps this at 1 by construction)."""
    data, reg, grad_fn, params0 = _problem(seed=12)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=13)
    alg = _dprox(reg)
    eng, state, m = _run(
        alg, grad_fn, data.n_clients,
        EngineConfig(chunk_rounds=4,
                     clock=DeterministicClock(per_client=(8.0, 1.0, 1.0,
                                                          1.0, 1.0, 1.0)),
                     buffer_size=3, staleness=Staleness("poly", correct=True),
                     queue_depth=3),
        params0, sup, 16)
    inflight = np.asarray(eng._sched_state.slot_filled).sum(axis=0)
    assert inflight.max() > 1  # someone raced ahead of delivery
    assert np.isfinite(m["train_loss"]).all()
    assert (np.diff(m["vtime"]) >= 0).all()
    # FIFO: in-flight deliver times per client are distinct and ordered
    dt = np.asarray(eng._sched_state.deliver_time)
    filled = np.asarray(eng._sched_state.slot_filled)
    for c in range(data.n_clients):
        times = np.sort(dt[filled[:, c], c])
        assert (np.diff(times) >= 0).all()


def test_queue_trains_and_is_chunk_invariant():
    data, reg, grad_fn, params0 = _problem(seed=13)
    sup = ArraySupplier.from_dataset(data, 3, 8, seed=14)
    alg = _dprox(reg)
    states = []
    for ch in (1, 4):
        _, s, m = _run(alg, grad_fn, data.n_clients,
                       EngineConfig(chunk_rounds=ch,
                                    clock=StragglerClock(slowdown=4.0),
                                    buffer_size=3, queue_depth=2,
                                    transport=TopK(ratio=0.5),
                                    staleness=Staleness("poly",
                                                        correct=True)),
                       params0, sup, 12)
        assert np.isfinite(m["train_loss"]).all()
        states.append(s)
    _assert_states_equal(states[0], states[1])


# ---------------------------------------------------------------------------
# prefetch donation
# ---------------------------------------------------------------------------


def test_prefetch_chunks_declared_donatable():
    # donation only pays on accelerators (BENCH_exec measured the donate
    # variant at 0.87x of plain prefetch on CPU), so the declaration is
    # gated on the backend: donatable iff prefetch + minibatch + accelerator
    data, _, _, _ = _problem(seed=14)
    on_accel = jax.default_backend() != "cpu"
    assert (ArraySupplier.from_dataset(data, 3, 4, prefetch=True)
            .donate_chunks == on_accel)
    assert not ArraySupplier.from_dataset(data, 3, 4).donate_chunks
    # full-batch mode serves broadcast VIEWS of the cache: never donatable
    assert not ArraySupplier.from_dataset(data, 3, None,
                                          prefetch=True).donate_chunks


def test_prefetch_donation_trajectory_identical():
    data, reg, grad_fn, params0 = _problem(seed=15)
    alg = _dprox(reg)
    on_accel = jax.default_backend() != "cpu"
    states = []
    for prefetch in (False, True):
        sup = ArraySupplier.from_dataset(data, 3, 8, seed=9,
                                         prefetch=prefetch)
        eng = RoundEngine(alg, grad_fn, data.n_clients,
                          EngineConfig(chunk_rounds=4))
        state = eng.init(params0)
        state, _ = eng.run(state, sup, 10, seed=0)
        assert eng._donate_batches == (prefetch and on_accel)
        states.append(state)
    _assert_states_equal(states[0], states[1])
