"""Infrastructure tests: checkpointing, serving engine, data pipelines,
roofline collective parser, sharding rule resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or fixed-grid fallback

# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt

    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2,), jnp.bfloat16),
                   "c": jnp.asarray(3, jnp.int32)},
    }
    p = tmp_path / "ck.npz"
    ckpt.save(tree, p, metadata={"round": 7})
    out = ckpt.restore(p, like=jax.tree_util.tree_map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    assert ckpt.metadata(p)["round"] == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.checkpoint import ckpt

    p = tmp_path / "ck.npz"
    ckpt.save({"a": jnp.ones((3,))}, p)
    with pytest.raises(ValueError):
        ckpt.restore(p, like={"a": jnp.ones((4,))})


def test_checkpoint_fed_state_roundtrip(tmp_path):
    from repro.checkpoint import ckpt
    from repro.core.algorithm import init_state
    from repro.models import logreg

    state = init_state(logreg.init_params(6), 3)
    p = tmp_path / "state.npz"
    ckpt.save(state, p, metadata={"arch": "logreg"})
    out = ckpt.restore(p, like=state)
    assert out.round.shape == state.round.shape
    assert jax.tree_util.tree_structure(out) == jax.tree_util.tree_structure(state)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def test_serving_engine_greedy_deterministic():
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving.engine import ServingEngine

    cfg = registry.get_smoke("stablelm_1_6b").with_overrides(
        param_dtype=jnp.float32)
    params, _ = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_len=48)
    prompts = np.arange(2 * 16, dtype=np.int32).reshape(2, 16) % cfg.vocab
    r1 = eng.generate(prompts, max_new_tokens=6)
    r2 = eng.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 6)
    assert np.all(r1.logprobs <= 0)


def test_serving_engine_rejects_encoder():
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.serving.engine import ServingEngine

    cfg = registry.get_smoke("hubert_xlarge")
    with pytest.raises(ValueError):
        ServingEngine(cfg, params=None)


# ---------------------------------------------------------------------------
# data pipelines
# ---------------------------------------------------------------------------


def test_logreg_generator_heterogeneity_scales_with_alpha_beta():
    from repro.data.synthetic import heterogeneity_index, logistic_heterogeneous

    lo = logistic_heterogeneous(n_clients=10, m_per_client=80, d=8,
                                alpha=0.01, beta=0.01, seed=1)
    hi = logistic_heterogeneous(n_clients=10, m_per_client=80, d=8,
                                alpha=50, beta=50, seed=1)
    assert heterogeneity_index(hi) > heterogeneity_index(lo)


def test_round_batches_shapes_and_determinism():
    from repro.data.synthetic import logistic_heterogeneous, make_round_batches

    data = logistic_heterogeneous(n_clients=4, m_per_client=30, d=6)
    b1 = make_round_batches(data, tau=3, batch_size=5,
                            rng=np.random.default_rng(7))
    b2 = make_round_batches(data, tau=3, batch_size=5,
                            rng=np.random.default_rng(7))
    assert b1["a"].shape == (4, 3, 5, 6)
    np.testing.assert_array_equal(b1["a"], b2["a"])
    full = make_round_batches(data, tau=2, batch_size=None,
                              rng=np.random.default_rng(0))
    assert full["a"].shape == (4, 2, 30, 6)


def test_token_streams_are_client_specific():
    from repro.data.synthetic import token_stream_heterogeneous

    s = token_stream_heterogeneous(3, 64, 4, vocab=64, seed=0)
    assert s.shape == (3, 4, 64)
    # bigram statistics should differ across clients
    def bigram_hist(x):
        h = np.zeros((64, 64))
        for seq in x.reshape(-1, 64):
            for a, b in zip(seq[:-1], seq[1:]):
                h[a, b] += 1
        return h / h.sum()

    h0, h1 = bigram_hist(s[0]), bigram_hist(s[1])
    assert np.abs(h0 - h1).sum() > 0.5


# ---------------------------------------------------------------------------
# roofline parser + sharding rules
# ---------------------------------------------------------------------------


def test_collective_parser_shapes_and_groups():
    from repro.roofline.analysis import parse_collectives

    hlo = """
  %ag = bf16[1024,128]{1,0} all-gather(%x), replica_groups=[32,16]<=[512], dimensions={0}
  %ar = f32[256]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = (f32[64]{0}, f32[64]{0}) reduce-scatter(%a, %b), replica_groups=[64,8]<=[512]
  %cp = u8[16]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = parse_collectives(hlo)
    ops = [o[0] for o in out]
    assert ops == ["all-gather", "all-reduce", "reduce-scatter",
                   "collective-permute"]
    ag = out[0]
    assert ag[1] == 1024 * 128 * 2 and ag[2] == 16
    ar = out[1]
    assert ar[1] == 256 * 4 and ar[2] == 4
    rs = out[2]
    assert rs[1] == 2 * 64 * 4 and rs[2] == 8
    assert out[3][3] == 16  # permute moves its payload once


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_spec_for_never_overassigns(seed):
    """Property: resolved specs always divide dims and never reuse a mesh
    axis within one tensor."""
    from repro.launch.sharding import _COMMON_PARAMS, spec_for

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}

    rng = np.random.default_rng(seed)
    axes_pool = list(_COMMON_PARAMS)
    ndim = rng.integers(1, 4)
    axes = tuple(rng.choice(axes_pool) for _ in range(ndim))
    shape = tuple(int(rng.choice([1, 8, 16, 64, 100352, 131072, 7, 24]))
                  for _ in range(ndim))
    spec = spec_for(shape, axes, _COMMON_PARAMS, FakeMesh())
    used = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        sz = 1
        for nm in names:
            assert nm not in used
            used.append(nm)
            sz *= FakeMesh.shape[nm]
        assert dim % sz == 0


# ---------------------------------------------------------------------------
# jax-compat shims stay the only callers of version-sensitive jax APIs
# ---------------------------------------------------------------------------


def test_version_sensitive_jax_apis_only_called_through_shims():
    """Three jax APIs moved or changed shape across the versions this repo
    supports, and each has exactly one compat shim:

      * ``jax.shard_map``          -> ``repro.models.moe_ep._shard_map``
      * ``jax.sharding.AxisType``  -> ``repro.launch.mesh.make_mesh_compat``
      * ``compiled.cost_analysis()`` ->
        ``repro.roofline.analysis.cost_analysis_dict``

    A raw call anywhere else reintroduces the version skew the shims
    exist to absorb, so this test greps the source tree for them.
    Comments and docstrings are stripped line-wise (good enough: the
    forbidden tokens never span lines).
    """
    import io
    import pathlib
    import re
    import tokenize

    src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    shims = {src / "models" / "moe_ep.py",
             src / "launch" / "mesh.py",
             src / "roofline" / "analysis.py"}
    patterns = {
        "jax.shard_map": re.compile(r"\bjax\s*\.\s*shard_map\b"),
        "jax.sharding.AxisType": re.compile(
            r"\bjax\s*\.\s*sharding\s*\.\s*AxisType\b"),
        ".cost_analysis()": re.compile(r"\.\s*cost_analysis\s*\("),
    }
    offenders = []
    for path in sorted(src.rglob("*.py")):
        if path in shims:
            continue
        text = path.read_text()
        # drop comments + string literals so prose mentions don't trip it
        code_lines = {}
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(text).readline):
                if tok.type in (tokenize.COMMENT, tokenize.STRING):
                    continue
                if tok.type == tokenize.NAME or tok.type == tokenize.OP:
                    code_lines.setdefault(tok.start[0], []).append(
                        tok.string)
        except tokenize.TokenError:
            pytest.fail(f"could not tokenize {path}")
        for lineno, toks in code_lines.items():
            line = " ".join(toks)
            for name, pat in patterns.items():
                if pat.search(line):
                    offenders.append(
                        f"{path.relative_to(src.parent)}:{lineno} "
                        f"calls {name} directly")
    assert not offenders, (
        "version-sensitive jax APIs must go through their compat shims "
        "(repro.launch.mesh.make_mesh_compat, repro.models.moe_ep."
        "_shard_map, repro.roofline.analysis.cost_analysis_dict):\n"
        + "\n".join(offenders))
