"""Ablation tests for algorithm variants beyond the paper's own experiments."""
import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.algorithm import DProxConfig
from repro.data.synthetic import make_round_batches
from repro.fed.simulator import DProxAlgorithm, run


def test_linear_prox_schedule_beats_fixed():
    """Section 2.2 item 4: the (t+1)*eta schedule reaches a far lower
    optimality floor than a fixed eta_tilde prox parameter."""
    from benchmarks.common import logreg_problem

    data, reg, grad_fn, full_g, params0, L = logreg_problem(
        n_clients=8, m=60, d=12, lam=0.01, x64=True)
    tau, eta_g = 8, 3.0
    eta_tilde = 0.5 / L
    eta = eta_tilde / (eta_g * tau)
    supplier = lambda r, rng: make_round_batches(data, tau, None, rng)
    floors = {}
    for sched in ("linear", "fixed"):
        cfg = DProxConfig(tau=tau, eta=eta, eta_g=eta_g, prox_schedule=sched)
        h = run(DProxAlgorithm(reg, cfg), params0, grad_fn, supplier, 8, 600,
                reg=reg, eta_tilde=eta_tilde, full_grad_fn=full_g,
                eval_every=600)
        floors[sched] = h.optimality[-1]
    assert floors["linear"] < 0.05 * floors["fixed"], floors
