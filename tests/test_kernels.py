"""Pallas kernel validation: shape/dtype sweeps against the jnp oracles.

All kernels run in interpret=True mode on CPU (the kernel body executes in
Python with real semantics); on TPU the same call sites compile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or fixed-grid fallback

from repro.kernels import ops, ref
from repro.kernels.fused_prox import fused_local_update_2d

# ---------------------------------------------------------------------------
# fused prox update
# ---------------------------------------------------------------------------

SHAPES = [(256, 128), (512, 128), (2048, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_fused_prox_2d_matches_ref(shape, dtype):
    rng = np.random.default_rng(0)
    zh = jnp.asarray(rng.normal(size=shape), dtype)
    g = jnp.asarray(rng.normal(size=shape), dtype)
    c = jnp.asarray(rng.normal(size=shape), dtype)
    eta, thresh = 0.37, 0.21
    got_zh, got_z = fused_local_update_2d(zh, g, c, eta, thresh,
                                          interpret=True, block_rows=256)
    exp_zh, exp_z = ref.fused_local_update(zh, g, c, eta, thresh)
    # kernel accumulates in fp32 then rounds once; the bf16 ref rounds every
    # op, so allow 1-ulp relative slack for bf16
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    rtol = 0 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(got_zh, np.float32),
                               np.asarray(exp_zh, np.float32), atol=tol, rtol=rtol)
    np.testing.assert_allclose(np.asarray(got_z, np.float32),
                               np.asarray(exp_z, np.float32), atol=tol, rtol=rtol)


@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(1, 5000),
       eta=st.floats(1e-4, 2.0),
       lam=st.floats(0.0, 1.0))
@settings(max_examples=15, deadline=None)
def test_fused_prox_pytree_arbitrary_sizes(seed, n, eta, lam):
    """The ops wrapper pads/reshapes arbitrary pytrees correctly."""
    rng = np.random.default_rng(seed)
    tree = {
        "w": jnp.asarray(rng.normal(size=(n,)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(3, 7)), jnp.float32),
    }
    g = jax.tree_util.tree_map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), tree)
    c = jax.tree_util.tree_map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), tree)
    got_zh, got_z = ops.fused_local_update(tree, g, c, eta, lam,
                                           interpret=True, block_rows=8)
    exp_zh, exp_z = jax.tree_util.tree_map(
        lambda a, b, d: ref.fused_local_update(a, b, d, eta, lam)[0],
        tree, g, c), jax.tree_util.tree_map(
        lambda a, b, d: ref.fused_local_update(a, b, d, eta, lam)[1],
        tree, g, c)
    for k in tree:
        np.testing.assert_allclose(np.asarray(got_zh[k]), np.asarray(exp_zh[k]),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(got_z[k]), np.asarray(exp_z[k]),
                                   atol=1e-6)


def test_fused_step_in_round_fn_matches_plain():
    """Algorithm 1 round with the fused kernel == plain jnp round."""
    from repro.core.algorithm import DProxConfig, init_state, make_round_fn
    from repro.core.prox import L1
    from repro.models import logreg
    from repro.data.synthetic import logistic_heterogeneous, make_round_batches
    from repro.utils import tree as tu

    data = logistic_heterogeneous(n_clients=4, m_per_client=20, d=12, seed=0)
    data.features = (data.features / 50).astype(np.float32)
    reg = L1(lam=0.01)
    grad_fn = logreg.make_grad_fn()
    params0 = logreg.init_params(12)
    cfg = DProxConfig(tau=3, eta=0.05, eta_g=2.0)
    rf_plain = make_round_fn(cfg, reg, grad_fn)
    rf_fused = make_round_fn(cfg, reg, grad_fn, use_fused_kernel=True)
    s1 = init_state(params0, 4)
    s2 = init_state(params0, 4)
    rng = np.random.default_rng(0)
    for _ in range(2):
        batches = make_round_batches(data, cfg.tau, 8, rng)
        s1, _ = rf_plain(s1, batches)
        s2, _ = rf_fused(s2, batches)
    diff = float(tu.tree_norm(tu.tree_sub(s1.x_bar, s2.x_bar)))
    assert diff < 1e-5, f"fused round diverged from reference: {diff}"


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,d,bq,bk", [(128, 64, 64, 64), (256, 128, 128, 128),
                                       (512, 64, 128, 64)])
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_flash_attention_causal_matches_ref(s, d, bq, bk, dtype):
    rng = np.random.default_rng(1)
    shape = (2, 3, s, d)
    q = jnp.asarray(rng.normal(size=shape) * 0.5, dtype)
    k = jnp.asarray(rng.normal(size=shape) * 0.5, dtype)
    v = jnp.asarray(rng.normal(size=shape) * 0.5, dtype)
    from repro.kernels.flash_attention import flash_attention

    got = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    exp = ref.flash_attention(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_flash_attention_window_softcap(window, softcap):
    rng = np.random.default_rng(2)
    shape = (1, 2, 256, 64)
    q = jnp.asarray(rng.normal(size=shape) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=shape) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=shape) * 0.5, jnp.float32)
    from repro.kernels.flash_attention import flash_attention

    got = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, bq=64, bk=64, interpret=True)
    exp = ref.flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)


def test_gqa_wrapper_matches_model_attention():
    """ops.gqa_flash_attention == the model's _sdpa path (GQA, causal)."""
    from repro.models import layers as L

    rng = np.random.default_rng(3)
    b, s, h, kh, d = 2, 128, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, d)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, d)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, d)) * 0.3, jnp.float32)
    got = ops.gqa_flash_attention(q, k, v, causal=True, interpret=True)
    mask = L.causal_mask(s, s)[None, None]
    exp = L._sdpa(q, k, v, mask, 1.0 / (d ** 0.5))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)
