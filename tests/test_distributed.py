"""Multi-device integration tests.

Run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the main pytest process must keep seeing 1 device for the smoke tests).
Asserts that the mesh-sharded federated round reproduces the single-device
simulator exactly, and that the sharding rule tables produce valid specs for
every architecture's parameter tree.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8
from repro.configs import registry
from repro.configs.base import InputShape
from repro.core.algorithm import DProxConfig, init_state, make_round_fn
from repro.core.prox import L1
from repro.launch.sharding import make_sharded_round_fn, shard_fed_state
from repro.launch import specs as sp
from repro.launch.mesh import make_debug_mesh
from repro.models import transformer as T
from repro.utils import tree as tu

cfg = registry.get_smoke("stablelm_1_6b").with_overrides(param_dtype=jnp.float32)
params, pspecs = T.init_model(jax.random.PRNGKey(0), cfg)
fcfg = DProxConfig(tau=2, eta=1e-3, eta_g=2.0)
reg = L1(lam=1e-5)
grad_fn = T.make_grad_fn(cfg)
shape = InputShape("t", "train", 64, 4)
batches = sp.train_batches(cfg, shape, n_clients=4, tau=2, abstract=False)

# single-device reference
ref_state = init_state(params, 4)
ref_round = jax.jit(make_round_fn(fcfg, reg, grad_fn))
ref1, _ = ref_round(ref_state, batches)
ref2, _ = ref_round(ref1, batches)

# sharded run (4 data x 2 model)
mesh = make_debug_mesh(8, model=2)
state = init_state(params, 4)
state, _ = shard_fed_state(mesh, state, pspecs, "A")
step, _ = make_sharded_round_fn(mesh, fcfg, reg, grad_fn, pspecs, "A", 4,
                                params)
s1, _ = step(state, batches)
s2, _ = step(s1, batches)

diff = float(tu.tree_norm(tu.tree_sub(s2.x_bar, ref2.x_bar)))
norm = float(tu.tree_norm(ref2.x_bar))
print("reldiff", diff / norm)
assert diff / norm < 1e-5, (diff, norm)

# sharding rules produce valid specs for every arch (full-size trees)
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
import jax
for arch in registry.ARCH_IDS:
    full = registry.get(arch)
    cap = {}
    def f(key, _full=full, _cap=cap):
        p, s = T.init_model(key, _full)
        _cap["s"] = s
        return p
    ps = jax.eval_shape(f, jax.random.PRNGKey(0))
    sh = shd.tree_shardings(ps, cap["s"], shd.server_param_rules(full.fed_plan), mesh)
    # every sharding must evenly divide its array
    for leaf, s in zip(jax.tree_util.tree_leaves(ps), jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: hasattr(x, "spec"))):
        for dim, ax in zip(leaf.shape, s.spec + (None,) * (len(leaf.shape) - len(s.spec))):
            if ax is not None:
                names = ax if isinstance(ax, tuple) else (ax,)
                sz = 1
                for n in names:
                    sz *= mesh.shape[n]
                assert dim % sz == 0, (arch, leaf.shape, s.spec)
print("ALL_OK")
"""


@pytest.mark.slow
def test_sharded_round_matches_simulator():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "ALL_OK" in out.stdout
